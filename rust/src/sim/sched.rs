//! Sharded asynchronous op execution: per-device submission queues
//! with completion frontiers (the ISSUE 2 tentpole; ARCHITECTURE.md
//! §Sharded scheduler), plus the **QoS plane** — per-class bandwidth
//! splits between foreground and recovery traffic (the ISSUE 5
//! tentpole; ARCHITECTURE.md §QoS plane, OPERATIONS.md §QoS tuning).
//!
//! SAGE absorbs Exascale I/O by letting many devices service one
//! logical operation concurrently (§3.1–§3.2 of the paper: multi-tier
//! enclosures, SNS striping). The [`IoScheduler`] is the simulation's
//! expression of that: every [`Device`] is an independent virtual-time
//! server with its own **shard** — a submission queue plus a
//! *completion frontier* (the virtual time its queue runs dry). A
//! batch of unit I/Os is dispatched to home-device shards in one pass;
//! draining the shards advances each device independently, so units on
//! different devices overlap in virtual time and a degraded/slow
//! device only delays the requests that actually queue on it. The
//! batch completes at the **max over per-device frontiers** — not at a
//! serial fold over units (`mero::sns_serial` preserves the fold as
//! the differential oracle; `tests/prop_sched.rs` checks sharded
//! completion <= serial completion on every sampled geometry).
//!
//! §Perf: submissions to one shard that share a timestamp, size,
//! access pattern and [`TrafficClass`] coalesce into a
//! **device-contiguous run**, accounted with ONE [`Device::io_run`]
//! call instead of one [`Device::io`] call per unit — the ROADMAP
//! "batch the virtual-time device accounting" item. Coalescing never
//! changes virtual time: a run of `n` equal I/Os queued back-to-back
//! completes exactly when `n` chained `io()` calls would.
//!
//! ## The QoS plane (§3.2.1 repair throttling)
//!
//! The recovery plane (SNS repair, proactive drains, HSM migration,
//! degraded-read reconstruction) shares these shards with foreground
//! op groups. §3.2.1 calls out repair throttling as essential once
//! rebuild traffic competes with applications, so every submission
//! carries a [`TrafficClass`] and each shard enforces a configurable
//! bandwidth split ([`QosConfig`]) as **interleaved run scheduling
//! with per-class frontiers**:
//!
//! * every shard keeps one completion frontier per class, all seeded
//!   from the device's queue tail at the scheduler's first touch (the
//!   *base*);
//! * a **capped** class (`share < 1.0`, e.g. Repair at the default
//!   0.30) yields to already-committed foreground work and then
//!   proceeds at `share` of the device rate — its runs are stretched
//!   `1/share`× in virtual time on its own frontier, which is exactly
//!   the static throttle real systems apply to rebuild traffic;
//! * **foreground** (and any class left uncapped) runs at full device
//!   rate, reduced to `1 − Σ(shares)` until every committed
//!   capped-class frontier on the shard is behind it (frontiers, not
//!   busy intervals, are what shards track — a deliberately
//!   conservative approximation that stays deterministic and can only
//!   under-serve foreground relative to the fluid model, never beat
//!   FIFO's worst case) — so a checkpoint racing a rebuild proceeds
//!   at 70% speed instead of queueing behind the whole rebuild;
//! * with NO capped backlog the math degenerates to the single-FIFO
//!   pre-QoS schedule **bit-exactly**, and a config with every share
//!   at 1.0 ([`QosConfig::unlimited`], the [`IoScheduler::new`]
//!   default) takes the preserved pre-QoS path outright — both pinned
//!   by `tests/prop_qos.rs`.
//!
//! ### Work-conserving borrowing (ISSUE 10)
//!
//! The static stretch deliberately idles `1 − share` of a device even
//! when foreground never shows up — wasted bandwidth the paper's
//! percipient-storage goal forbids at Exascale utilization. With
//! [`QosConfig::work_conserving`] set, a capped run whose shard has
//! **no committed foreground backlog** at the run's submit time
//! (foreground frontier at or before `submit_at`) *borrows* the idle
//! headroom and runs at full device rate; a capped run submitted
//! after a foreground commit sees the foreground frontier ahead of it
//! and pays the full static `1/share` stretch — the cap holds the
//! instant foreground arrives. Foreground itself is never slower
//! than under the static split: borrowing only *shortens* the capped
//! frontiers its `contended_end` integration spans. The pre-change
//! static scheduler is preserved verbatim as
//! [`qos_static_oracle`](crate::sim::qos_static_oracle) and
//! `tests/prop_qos_conserving.rs` pins work-conserving completion ≤
//! static completion for EVERY class on every sampled geometry, with
//! borrowed headroom observable per shard via
//! [`QosShardReport::lent`].
//!
//! The split never changes *what* is stored or read — only *when*
//! completions land (byte-equivalence, determinism and the cap bound
//! are property-tested in `tests/prop_qos.rs`; the foreground win is
//! measured by `benches/ablate_qos.rs`). Shares are observable per
//! shard through [`IoScheduler::qos_report`] /
//! [`QosShardReport::observed_share`] — the per-class frontier tables
//! OPERATIONS.md teaches operators to read.
//!
//! ## The multi-tenant plane (ISSUE 7; ARCHITECTURE.md §Multi-tenant
//! plane)
//!
//! Since ISSUE 7 ONE scheduler instance is shared cluster-wide by
//! every Clovis session ([`Client::sched`](crate::clovis::Client)),
//! and every submission carries a [`TenantId`] alongside its
//! [`TrafficClass`]. Two mechanisms make that sharing safe:
//!
//! * **Epochs** ([`IoScheduler::begin_epoch`]): each adopting op group
//!   opens a fresh scheduling epoch. A shard whose queue is idle at
//!   the epoch's start re-captures its base and per-class frontiers
//!   from the device queue tail — exactly what a fresh private
//!   scheduler would have done — so back-to-back sessions reproduce
//!   the pre-ISSUE-7 schedules **bit-exactly** (`tests/prop_tenant.rs`
//!   pins this against a reset-per-session oracle). A shard still busy
//!   past the epoch start keeps its lanes: the new session *contends*
//!   with the in-flight work, which is the phenomenon private
//!   schedulers could never represent. [`IoScheduler::wait_all`],
//!   [`IoScheduler::frontiers`] and [`IoScheduler::qos_report`] scope
//!   to the current epoch, so concurrent groups never see each other's
//!   completions.
//! * **Weighted tenant lanes** ([`TenantShares`]): with two or more
//!   registered tenants the shard schedules each `(tenant, class)`
//!   pair on its own frontier lane at
//!   `weight/Σweights × class share` of the device rate — the
//!   per-class frontier machinery generalized to weighted per-tenant
//!   fair shares. A single-tenant config ([`TenantShares::single`])
//!   keeps the plane inactive and the schedule bit-identical to the
//!   per-class path. Shares are observable per shard through
//!   [`IoScheduler::tenant_report`] /
//!   [`TenantShardReport::observed_share`].
//!
//! ## §Perf: dense tables (ISSUE 8 sim-core overhaul)
//!
//! At soak scale (`SoakConfig::full`: thousands of objects, millions of
//! submissions) the per-submission `BTreeMap` walks and per-run `Vec`
//! allocations dominated wall-clock time, so the scheduler's interior
//! is **dense**:
//!
//! * `shards` is a `Vec<Shard>` indexed directly by device id (device
//!   ids are dense: `Cluster` stores devices in a `Vec`), with a sorted
//!   `touched` list preserving the old BTreeMap's device-order
//!   iteration for drains and reports — results are bit-identical and
//!   insert-order independent (pinned by the tests below against
//!   [`sched_oracle`](crate::sim::sched_oracle), the preserved
//!   BTreeMap implementation);
//! * per-shard tenant lanes are a sorted `Vec` keyed by
//!   `(tenant, class)` with binary-search lookup — same deterministic
//!   report order, no per-lane node allocations;
//! * ticket storage recycles: drained runs return their `tickets` Vecs
//!   to a pool `submit` reuses, `pending` queues keep their capacity
//!   across drains, and [`IoScheduler::begin_epoch`] truncates the
//!   redeemed `completions` table (tickets never cross an epoch — the
//!   `begin_epoch` pending==0 contract) — so a long soak reaches a
//!   steady state with no per-session allocation in `submit`/`drain`;
//! * [`IoScheduler::frontiers_into`] / [`IoScheduler::qos_report_into`]
//!   / [`IoScheduler::tenant_report_into`] fill caller-owned buffers so
//!   hot diagnostic loops (benches, the soak) reuse capacity instead of
//!   allocating a fresh report per session.

use std::collections::BTreeMap;

use super::clock::SimTime;
use super::device::{Access, Device, IoOp};

/// Handle for one submitted I/O; redeem with
/// [`IoScheduler::completion`] after the next [`IoScheduler::drain`].
pub type Ticket = usize;

/// Number of traffic classes (the length of per-class state arrays).
pub const N_CLASSES: usize = 3;

/// Foreground rate floor under pathological configs (both background
/// classes capped so high that `1 − Σ(shares)` would go non-positive).
const MIN_FOREGROUND_RATE: f64 = 0.05;

/// QoS traffic class a submission dispatches under (§3.2.1 repair
/// throttling). Application and gateway I/O is [`Foreground`]; SNS
/// repair, proactive drains and degraded-read reconstruction submit as
/// [`Repair`]; HSM data movement submits as [`Migration`]. The class
/// is scheduler state ([`IoScheduler::set_class`]) so deep call chains
/// (stripe writes inside a repair) inherit it without threading a
/// parameter through every layer.
///
/// [`Foreground`]: TrafficClass::Foreground
/// [`Repair`]: TrafficClass::Repair
/// [`Migration`]: TrafficClass::Migration
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TrafficClass {
    /// Application/gateway I/O — always runs at full device rate,
    /// reduced only while committed capped backlog overlaps it.
    Foreground,
    /// Rebuild traffic: SNS repair, proactive drains, degraded-read
    /// survivor reads. Capped at [`QosConfig::repair_share`].
    Repair,
    /// HSM tiering traffic. Capped at [`QosConfig::migration_share`].
    Migration,
}

impl TrafficClass {
    /// Every class, in per-class state-array order.
    pub const ALL: [TrafficClass; N_CLASSES] =
        [TrafficClass::Foreground, TrafficClass::Repair, TrafficClass::Migration];

    /// Index into per-class state arrays (`[_; N_CLASSES]`).
    pub fn index(self) -> usize {
        match self {
            TrafficClass::Foreground => 0,
            TrafficClass::Repair => 1,
            TrafficClass::Migration => 2,
        }
    }

    /// Human-readable label (frontier tables, bench output).
    pub fn label(self) -> &'static str {
        match self {
            TrafficClass::Foreground => "foreground",
            TrafficClass::Repair => "repair",
            TrafficClass::Migration => "migration",
        }
    }
}

/// Per-class bandwidth split a scheduler enforces on every shard
/// (§3.2.1 repair throttling; OPERATIONS.md §QoS tuning has the
/// operator's guide). A share of `1.0` leaves that class uncapped (it
/// rides the foreground lane); a share below `1.0` caps the class at
/// that fraction of per-device throughput while it is backlogged.
///
/// `Default` is the **sane split** every Clovis session inherits from
/// [`Cluster::qos`](crate::cluster::Cluster): repair at 0.30,
/// migration at 0.20 — foreground keeps at least half of every device
/// even with both background classes saturated. Zero background
/// traffic makes the split free (bit-identical to
/// [`QosConfig::unlimited`]); setting every share to 1.0 reproduces
/// the pre-QoS FIFO frontiers exactly (`tests/prop_qos.rs` pins both).
///
/// With [`work_conserving`](QosConfig::work_conserving) set (ISSUE 10;
/// `[qos] work_conserving = true` in TOML, or
/// [`QosConfig::conserving`]), the caps become **feedback throttles**:
/// a capped lane with no committed foreground backlog ahead of its
/// submission borrows the idle foreground headroom and runs at full
/// device rate; the instant foreground commits ahead of a capped
/// submission, the static `1/share` stretch reapplies. The static
/// split is preserved verbatim in
/// [`qos_static_oracle`](crate::sim::qos_static_oracle) and
/// `tests/prop_qos_conserving.rs` pins work-conserving completion ≤
/// static completion for every class on every sampled geometry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QosConfig {
    /// Fraction of per-device throughput [`TrafficClass::Repair`] may
    /// use whenever it runs (clamped to `[0.01, 1.0]`). By default
    /// this is a STATIC throttle: the cap applies even with no
    /// foreground contention — an idle-foreground rebuild (or a
    /// degraded read's reconstruction) deliberately leaves `1 − share`
    /// headroom so latency-sensitive work always finds the device
    /// responsive. See [`QosConfig::work_conserving`] for the
    /// borrowing alternative.
    pub repair_share: f64,
    /// Fraction for [`TrafficClass::Migration`] (clamped likewise;
    /// same throttle semantics).
    pub migration_share: f64,
    /// Work-conserving borrowing (ISSUE 10). `false` (the default)
    /// keeps the PR-5 static throttle bit-exactly. `true` lets a
    /// capped class borrow unused foreground headroom whenever the
    /// shard has no committed foreground backlog at the run's submit
    /// time; foreground arrivals reimpose the cap on every capped run
    /// submitted after them (the reclaim bound,
    /// `tests/prop_qos_conserving.rs`). Borrowed headroom is reported
    /// per shard in [`QosShardReport::lent`].
    pub work_conserving: bool,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            repair_share: 0.30,
            migration_share: 0.20,
            work_conserving: false,
        }
    }
}

impl QosConfig {
    /// No split at all: every class at full rate on one FIFO queue —
    /// the pre-QoS semantics, and what [`IoScheduler::new`] uses so
    /// self-contained store operations and the differential oracles
    /// stay bit-identical to their pre-QoS selves.
    pub fn unlimited() -> Self {
        QosConfig {
            repair_share: 1.0,
            migration_share: 1.0,
            work_conserving: false,
        }
    }

    /// The default split with work-conserving borrowing on — the
    /// ISSUE 10 feedback mode (`repair 0.30 / migration 0.20`, idle
    /// foreground headroom lent to backlogged capped lanes).
    pub fn conserving() -> Self {
        QosConfig { work_conserving: true, ..QosConfig::default() }
    }

    /// Effective share of `class` (foreground is always 1.0;
    /// background shares are clamped to `[0.01, 1.0]`).
    pub fn share(&self, class: TrafficClass) -> f64 {
        match class {
            TrafficClass::Foreground => 1.0,
            TrafficClass::Repair => self.repair_share.clamp(0.01, 1.0),
            TrafficClass::Migration => self.migration_share.clamp(0.01, 1.0),
        }
    }

    /// True when any class is capped — i.e. the per-class-frontier
    /// schedule is in effect. When false the scheduler takes the
    /// preserved pre-QoS FIFO path (bit-exact).
    pub fn active(&self) -> bool {
        TrafficClass::ALL.iter().any(|&c| self.share(c) < 1.0)
    }
}

/// Identity of the tenant a submission is dispatched for (ISSUE 7
/// multi-tenant plane). Tenants are registered with a weight through
/// [`TenantShares::register`] (admission control lives at the Clovis
/// layer: `Client::session_as` refuses unregistered ids); the id is
/// scheduler state ([`IoScheduler::set_tenant`]) exactly like the
/// [`TrafficClass`], so deep call chains inherit it without threading
/// a parameter through every layer.
pub type TenantId = usize;

/// The implicit tenant every client starts with ([`Client::session`]
/// sessions run as this id).
///
/// [`Client::session`]: crate::clovis::Client::session
pub const DEFAULT_TENANT: TenantId = 0;

/// Weighted per-tenant fair shares (ISSUE 7): the admission list of
/// registered tenants, each with a weight. With a single registered
/// tenant the plane is **inactive** — every shard schedules on the
/// per-class lanes exactly as before, bit-for-bit
/// (`tests/prop_tenant.rs`). With two or more tenants, tenant `t`
/// runs at `weight(t) / Σ weights` of each device (multiplied by its
/// class share for capped classes) on its own per-shard frontier lane
/// — a STATIC weighted split with the same semantics as the
/// [`QosConfig`] throttle, so no tenant can starve another and no
/// lane ever blocks on another lane's backlog (the no-starvation
/// property `prop_tenant.rs` pins).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantShares {
    /// Registered tenants → weight (the admission list).
    weights: BTreeMap<TenantId, f64>,
}

impl Default for TenantShares {
    fn default() -> Self {
        TenantShares::single()
    }
}

impl TenantShares {
    /// The single-tenant world every cluster starts in:
    /// [`DEFAULT_TENANT`] at weight 1.0, plane inactive.
    pub fn single() -> Self {
        let mut weights = BTreeMap::new();
        weights.insert(DEFAULT_TENANT, 1.0);
        TenantShares { weights }
    }

    /// Admit a new tenant with `weight` (negative weights clamp to
    /// 0.0, which floors the tenant at the minimum 0.01 lane share);
    /// returns its id. Ids are dense and deterministic: the first
    /// registration after [`TenantShares::single`] is tenant 1.
    pub fn register(&mut self, weight: f64) -> TenantId {
        let id = self.weights.keys().next_back().map_or(0, |&k| k + 1);
        self.weights.insert(id, weight.max(0.0));
        id
    }

    /// Re-weight an already-registered tenant (or admit an explicit
    /// id, e.g. when mirroring another cluster's tenant table).
    pub fn set_weight(&mut self, tenant: TenantId, weight: f64) {
        self.weights.insert(tenant, weight.max(0.0));
    }

    /// True when `tenant` has been admitted.
    pub fn is_registered(&self, tenant: TenantId) -> bool {
        self.weights.contains_key(&tenant)
    }

    /// Registered `(tenant, weight)` pairs in id order.
    pub fn tenants(&self) -> impl Iterator<Item = (TenantId, f64)> + '_ {
        self.weights.iter().map(|(&t, &w)| (t, w))
    }

    /// Number of registered tenants.
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    /// True when the admission list is empty (never the case for
    /// tables built from [`TenantShares::single`]).
    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// True when per-tenant scheduling is in effect (two or more
    /// registered tenants). When false the scheduler takes the
    /// per-class path unchanged (bit-exact).
    pub fn active(&self) -> bool {
        self.weights.len() >= 2
    }

    /// Effective device share of `tenant`: `weight / Σ weights`
    /// clamped to `[0.01, 1.0]`; 1.0 while the plane is inactive.
    /// Unregistered ids (admission control at the Clovis layer
    /// prevents them reaching a scheduler) degrade to a minimal lane
    /// instead of panicking.
    pub fn share(&self, tenant: TenantId) -> f64 {
        if !self.active() {
            return 1.0;
        }
        let total: f64 = self.weights.values().sum();
        match self.weights.get(&tenant) {
            Some(&w) => (w / total.max(f64::MIN_POSITIVE)).clamp(0.01, 1.0),
            None => (1.0 / (total + 1.0)).clamp(0.01, 1.0),
        }
    }
}

/// A device-contiguous run: consecutive submissions to one shard with
/// identical timestamp/size/op/access/class/tenant, accounted as one
/// device call.
#[derive(Debug)]
struct Run {
    submit_at: SimTime,
    size: u64,
    op: IoOp,
    access: Access,
    class: TrafficClass,
    tenant: TenantId,
    tickets: Vec<Ticket>,
}

/// One `(tenant, class)` frontier lane of a shard (multi-tenant
/// plane): the virtual time the lane's committed work ends, and the
/// REAL device seconds it consumed.
#[derive(Debug, Clone, Copy)]
struct TenantLane {
    frontier: SimTime,
    busy: f64,
}

/// One device's slice of the scheduler: pending runs, the overall
/// frontier, and the QoS plane's per-class state.
#[derive(Debug, Default)]
struct Shard {
    /// True once this shard has seen a submission. Dense `shards`
    /// storage allocates default slots for every device id below the
    /// highest touched one; only used shards appear in `touched` (and
    /// therefore in drains and reports).
    used: bool,
    pending: Vec<Run>,
    /// Virtual time up to which the device's queue has been driven
    /// (max over all classes).
    frontier: SimTime,
    /// Device `busy_until` captured before this scheduler's first
    /// commit on the shard — external work (earlier sessions) ends
    /// here; per-class frontiers are seeded from it.
    base: Option<SimTime>,
    /// Per-class completion frontiers (valid once `base` is set).
    class_frontier: [SimTime; N_CLASSES],
    /// Per-class accumulated device service time (REAL device seconds
    /// of work, not stretched wall span) — the numerator of
    /// [`QosShardReport::observed_share`].
    class_busy: [f64; N_CLASSES],
    /// Per-class virtual seconds of foreground headroom lent to the
    /// class by work-conserving borrowing: the `1/share` stretch each
    /// borrowed run avoided ([`QosConfig::work_conserving`]). Always
    /// zero under the static split.
    class_lent: [f64; N_CLASSES],
    /// Scheduling epoch this shard last committed work under. A shard
    /// entering a NEW epoch while idle (its frontier at or before the
    /// epoch start) re-captures `base`, frontiers and lanes from the
    /// device queue tail — the fresh-private-scheduler semantics; a
    /// shard still busy keeps them and the epochs contend.
    epoch: u64,
    /// Max completion committed during the current epoch only — what
    /// [`IoScheduler::wait_all`] folds, so one group never waits on
    /// another group's completions.
    epoch_frontier: SimTime,
    /// Per-`(tenant, class index)` frontier lanes, kept sorted by key
    /// (populated only while [`TenantShares::active`]; binary-search
    /// lookup, same deterministic order the old BTreeMap iterated in).
    lanes: Vec<((TenantId, usize), TenantLane)>,
}

impl Shard {
    /// Binary-search lookup in the sorted `(tenant, class)` lane table.
    fn lane(&self, key: (TenantId, usize)) -> Option<&TenantLane> {
        self.lanes
            .binary_search_by(|(k, _)| k.cmp(&key))
            .ok()
            .map(|i| &self.lanes[i].1)
    }

    /// The lane for `key`, inserted at its sorted position on first
    /// touch (frontier seeded from `lane_base`) — the dense
    /// replacement for the old `BTreeMap::entry(..).or_insert(..)`.
    fn lane_entry(
        &mut self,
        key: (TenantId, usize),
        lane_base: SimTime,
    ) -> &mut TenantLane {
        let i = match self.lanes.binary_search_by(|(k, _)| k.cmp(&key)) {
            Ok(i) => i,
            Err(i) => {
                let lane = TenantLane { frontier: lane_base, busy: 0.0 };
                self.lanes.insert(i, (key, lane));
                i
            }
        };
        &mut self.lanes[i].1
    }
}

/// Per-shard QoS diagnostics: the per-class frontier table
/// (OPERATIONS.md §Reading the frontier tables). One row per shard
/// the scheduler has **drained** work on.
#[derive(Debug, Clone)]
pub struct QosShardReport {
    /// Device id of the shard.
    pub device: usize,
    /// Queue tail the shard inherited from earlier schedulers.
    pub base: SimTime,
    /// Overall completion frontier (max over classes).
    pub frontier: SimTime,
    /// Real device seconds of work each class consumed.
    pub class_busy: [f64; N_CLASSES],
    /// Per-class completion frontiers.
    pub class_frontier: [SimTime; N_CLASSES],
    /// Virtual seconds of foreground headroom lent to each class by
    /// work-conserving borrowing — the `1/share` stretch the class's
    /// borrowed runs avoided ([`QosConfig::work_conserving`]). All
    /// zero under the static split.
    pub lent: [f64; N_CLASSES],
}

impl QosShardReport {
    /// Observed device-time share of `class` over its active window
    /// `[base, class frontier]` — what the [`QosConfig`] cap bounds
    /// from above for capped classes (`tests/prop_qos.rs`). 0.0 when
    /// the class never ran on this shard.
    pub fn observed_share(&self, class: TrafficClass) -> f64 {
        let i = class.index();
        let window = self.class_frontier[i] - self.base;
        if window <= 0.0 || self.class_busy[i] <= 0.0 {
            return 0.0;
        }
        self.class_busy[i] / window
    }

    /// Committed backlog depth of the shard at virtual time `now`:
    /// how far the shard's frontier runs ahead of the clock, i.e. the
    /// virtual seconds of already-committed work a new arrival at
    /// `now` would queue behind. 0.0 for an idle (drained-past)
    /// shard. This is the congestion signal
    /// [`CongestionView`](crate::mero::pool::CongestionView) feeds
    /// into placement (ISSUE 10).
    pub fn backlog_depth(&self, now: SimTime) -> SimTime {
        (self.frontier - now).max(0.0)
    }

    /// Virtual seconds of foreground headroom lent to `class` by
    /// work-conserving borrowing on this shard (0.0 under the static
    /// split, or when the class never borrowed).
    pub fn lent_headroom(&self, class: TrafficClass) -> f64 {
        self.lent[class.index()]
    }
}

/// One `(tenant, class)` row of a [`TenantShardReport`].
#[derive(Debug, Clone)]
pub struct TenantLaneReport {
    /// Tenant the lane belongs to.
    pub tenant: TenantId,
    /// Traffic class of the lane.
    pub class: TrafficClass,
    /// Real device seconds of work the lane consumed.
    pub busy: f64,
    /// The lane's completion frontier.
    pub frontier: SimTime,
}

/// Per-shard multi-tenant diagnostics: the per-tenant frontier table
/// (OPERATIONS.md §Reading the per-tenant frontier tables) —
/// [`IoScheduler::qos_report`] generalized to `(tenant, class)` lanes.
/// Rows exist only while the tenant plane is active
/// ([`TenantShares::active`]).
#[derive(Debug, Clone)]
pub struct TenantShardReport {
    /// Device id of the shard.
    pub device: usize,
    /// Queue tail the shard inherited when its lanes were (re)seeded.
    pub base: SimTime,
    /// One row per `(tenant, class)` lane, in `(tenant, class)` order.
    pub lanes: Vec<TenantLaneReport>,
}

impl TenantShardReport {
    /// Observed device-time share of `tenant` over its active window
    /// `[base, max lane frontier]` — what the [`TenantShares`] weight
    /// bounds from above for single-class workloads
    /// (`tests/prop_tenant.rs`, `benches/ablate_tenants.rs`). 0.0 when
    /// the tenant never ran on this shard.
    pub fn observed_share(&self, tenant: TenantId) -> f64 {
        let mut busy = 0.0;
        let mut front = self.base;
        for lane in self.lanes.iter().filter(|l| l.tenant == tenant) {
            busy += lane.busy;
            front = front.max(lane.frontier);
        }
        let window = front - self.base;
        if window <= 0.0 || busy <= 0.0 {
            return 0.0;
        }
        busy / window
    }

    /// Completion frontier of `tenant` on this shard: the max over its
    /// lanes (the shard base when the tenant never ran here). Every
    /// tenant's frontier advancing past `base` is the no-starvation
    /// property `prop_tenant.rs` pins.
    pub fn tenant_frontier(&self, tenant: TenantId) -> SimTime {
        self.lanes
            .iter()
            .filter(|l| l.tenant == tenant)
            .fold(self.base, |t, l| t.max(l.frontier))
    }
}

/// The sharded op-execution scheduler. Since ISSUE 7 ONE instance is
/// the **cluster-wide scheduler** shared by every Clovis session
/// ([`Client::sched`](crate::clovis::Client)): each adopting op group
/// opens a scheduling *epoch* ([`IoScheduler::begin_epoch`]), and
/// [`IoScheduler::wait_all`]/[`IoScheduler::frontiers`]/
/// [`IoScheduler::qos_report`] scope to the current epoch so groups
/// never observe each other's completions. Self-contained store
/// operations and the serial oracles still build private throwaway
/// instances — an un-epoched scheduler behaves exactly as before.
/// [`IoScheduler::new`] enforces no split ([`QosConfig::unlimited`]);
/// Clovis op groups are built with [`IoScheduler::with_qos`] carrying
/// the cluster's [`QosConfig`].
#[derive(Debug)]
pub struct IoScheduler {
    /// Per-device shards, indexed directly by device id (dense; slots
    /// below the highest touched id exist but stay `used == false`
    /// until a submission lands on them).
    shards: Vec<Shard>,
    /// Device ids with a used shard, kept sorted — drains and reports
    /// iterate in device order, exactly like the old BTreeMap keys.
    touched: Vec<usize>,
    /// Recycled `Run::tickets` storage: drained runs park their empty
    /// Vecs here and [`IoScheduler::submit`] reuses them, so a
    /// steady-state soak stops allocating per run (§Perf).
    ticket_pool: Vec<Vec<Ticket>>,
    /// Completion time per ticket (valid after the draining pass;
    /// truncated by [`IoScheduler::begin_epoch`] — tickets are scoped
    /// to their epoch).
    completions: Vec<SimTime>,
    /// Device accounting calls issued (one per device-contiguous run).
    n_runs: u64,
    /// Logical I/Os submitted.
    n_ios: u64,
    /// The bandwidth split this scheduler enforces.
    qos: QosConfig,
    /// Class stamped on new submissions ([`IoScheduler::set_class`]).
    class: TrafficClass,
    /// Tenant stamped on new submissions ([`IoScheduler::set_tenant`]).
    tenant: TenantId,
    /// The weighted per-tenant split (inactive while single-tenant).
    tenants: TenantShares,
    /// Current scheduling epoch (0 until the first
    /// [`IoScheduler::begin_epoch`]; un-epoched schedulers keep every
    /// shard in epoch 0, preserving the one-group-per-scheduler
    /// behavior unchanged).
    epoch: u64,
    /// Virtual time the current epoch opened at — the idle test for
    /// per-shard re-seeding.
    epoch_start: SimTime,
    /// `n_runs` / `n_ios` snapshots at the epoch open, so per-epoch
    /// dispatch stats stay per-session on the shared instance.
    epoch_runs0: u64,
    epoch_ios0: u64,
}

impl Default for IoScheduler {
    fn default() -> Self {
        IoScheduler::with_qos(QosConfig::unlimited())
    }
}

impl IoScheduler {
    /// Empty scheduler with NO bandwidth split — the pre-QoS
    /// semantics, used by self-contained store operations and the
    /// serial oracles.
    pub fn new() -> Self {
        IoScheduler::default()
    }

    /// Empty scheduler enforcing `qos` on every shard. Clovis op
    /// groups pass the cluster's configured split here
    /// ([`OpGroup::with_qos`](crate::clovis::ops::OpGroup::with_qos)).
    pub fn with_qos(qos: QosConfig) -> Self {
        IoScheduler {
            shards: Vec::new(),
            touched: Vec::new(),
            ticket_pool: Vec::new(),
            completions: Vec::new(),
            n_runs: 0,
            n_ios: 0,
            qos,
            class: TrafficClass::Foreground,
            tenant: DEFAULT_TENANT,
            tenants: TenantShares::single(),
            epoch: 0,
            epoch_start: 0.0,
            epoch_runs0: 0,
            epoch_ios0: 0,
        }
    }

    /// The split this scheduler enforces.
    pub fn qos(&self) -> QosConfig {
        self.qos
    }

    /// Replace the bandwidth split. The cluster-wide scheduler syncs
    /// this from [`Cluster::qos`](crate::cluster::Cluster) at every
    /// session adoption, so config edits between sessions take effect
    /// exactly like they did with private per-group schedulers.
    /// Applies to subsequent drains only.
    pub fn set_qos(&mut self, qos: QosConfig) {
        self.qos = qos;
    }

    /// The tenant table this scheduler schedules against.
    pub fn tenants(&self) -> &TenantShares {
        &self.tenants
    }

    /// Replace the tenant table (synced from
    /// [`Cluster::tenants`](crate::cluster::Cluster) at every session
    /// adoption). Applies to subsequent drains only.
    pub fn set_tenants(&mut self, tenants: TenantShares) {
        self.tenants = tenants;
    }

    /// Set the [`TenantId`] stamped on subsequent submissions; returns
    /// the previous tenant (the [`IoScheduler::set_class`] pattern).
    pub fn set_tenant(&mut self, tenant: TenantId) -> TenantId {
        std::mem::replace(&mut self.tenant, tenant)
    }

    /// Run `f` with submissions stamped `tenant`, restoring the
    /// previous tenant on exit (the [`IoScheduler::with_class`]
    /// scoping primitive, for the tenant axis).
    pub fn with_tenant<T>(
        &mut self,
        tenant: TenantId,
        f: impl FnOnce(&mut Self) -> T,
    ) -> T {
        let prev = std::mem::replace(&mut self.tenant, tenant);
        let out = f(self);
        self.tenant = prev;
        out
    }

    /// Tenant currently stamped on submissions.
    pub fn current_tenant(&self) -> TenantId {
        self.tenant
    }

    /// Open a new scheduling epoch at virtual time `now` — what
    /// [`OpGroup::adopt`](crate::clovis::ops::OpGroup::adopt) calls
    /// when a session takes the cluster-wide scheduler. Shards drained
    /// under the new epoch re-seed their base/frontiers/lanes from the
    /// device queue tail **iff idle at `now`** (fresh-private-scheduler
    /// semantics, bit-exact); shards still busy past `now` keep their
    /// lanes and the epochs contend (see the module docs). Scopes
    /// [`IoScheduler::wait_all`] / [`IoScheduler::frontiers`] /
    /// [`IoScheduler::qos_report`] / [`IoScheduler::tenant_report`] and
    /// the `epoch_*` counters to work submitted from here on. Returns
    /// the new epoch id.
    pub fn begin_epoch(&mut self, now: SimTime) -> u64 {
        debug_assert_eq!(
            self.pending(),
            0,
            "begin_epoch with another group's submissions pending"
        );
        // tickets are redeemed within their epoch (the pending==0
        // contract above): recycle the completion table's storage
        // instead of growing it for the lifetime of the scheduler —
        // exactly what a fresh private scheduler's empty table gave
        // pre-ISSUE-7 sessions
        self.completions.clear();
        self.epoch += 1;
        self.epoch_start = now;
        self.epoch_runs0 = self.n_runs;
        self.epoch_ios0 = self.n_ios;
        self.epoch
    }

    /// Current scheduling epoch (0 = never adopted; pre-ISSUE-7
    /// single-group semantics).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Device accounting calls issued during the current epoch — the
    /// per-session view of [`IoScheduler::io_calls`] on the shared
    /// instance.
    pub fn epoch_io_calls(&self) -> u64 {
        self.n_runs - self.epoch_runs0
    }

    /// Logical unit I/Os submitted during the current epoch — the
    /// per-session view of [`IoScheduler::ios`].
    pub fn epoch_ios(&self) -> u64 {
        self.n_ios - self.epoch_ios0
    }

    /// Set the [`TrafficClass`] stamped on subsequent submissions;
    /// returns the previous class so call chains can save/restore
    /// (prefer [`IoScheduler::with_class`], which restores
    /// structurally).
    pub fn set_class(&mut self, class: TrafficClass) -> TrafficClass {
        std::mem::replace(&mut self.class, class)
    }

    /// Run `f` with submissions stamped `class`, restoring the
    /// previous class on exit — the one scoping primitive the
    /// recovery-plane entry points (`sns::repair_with`/`drain_with`,
    /// `Hsm::migrate_with`, degraded-read reconstruction) wrap their
    /// dispatch in, so the restore can never be skipped by an early
    /// return inside `f`.
    pub fn with_class<T>(
        &mut self,
        class: TrafficClass,
        f: impl FnOnce(&mut Self) -> T,
    ) -> T {
        let prev = std::mem::replace(&mut self.class, class);
        let out = f(self);
        self.class = prev;
        out
    }

    /// Class currently stamped on submissions.
    pub fn current_class(&self) -> TrafficClass {
        self.class
    }

    /// Queue one unit I/O on `device`'s shard at virtual time
    /// `submit_at`, under the current [`TrafficClass`]. Returns a
    /// [`Ticket`] redeemable for the completion time after the next
    /// [`IoScheduler::drain`]. Consecutive submissions to the same
    /// shard with identical parameters coalesce into one
    /// device-contiguous run (§Perf).
    pub fn submit(
        &mut self,
        device: usize,
        submit_at: SimTime,
        size: u64,
        op: IoOp,
        access: Access,
    ) -> Ticket {
        let ticket = self.completions.len();
        // placeholder until drained; never observed by correct callers
        self.completions.push(submit_at);
        self.n_ios += 1;
        let class = self.class;
        let tenant = self.tenant;
        if device >= self.shards.len() {
            self.shards.resize_with(device + 1, Shard::default);
        }
        let shard = &mut self.shards[device];
        if !shard.used {
            shard.used = true;
            if let Err(pos) = self.touched.binary_search(&device) {
                self.touched.insert(pos, device);
            }
        }
        if let Some(run) = shard.pending.last_mut() {
            if run.submit_at == submit_at
                && run.size == size
                && run.op == op
                && run.access == access
                && run.class == class
                && run.tenant == tenant
            {
                run.tickets.push(ticket);
                return ticket;
            }
        }
        let mut tickets = self.ticket_pool.pop().unwrap_or_default();
        tickets.push(ticket);
        shard.pending.push(Run {
            submit_at,
            size,
            op,
            access,
            class,
            tenant,
            tickets,
        });
        ticket
    }

    /// Execute every pending run against its device, advancing each
    /// shard's completion frontier independently. Returns the max
    /// completion time of the *drained* batch (0.0 if nothing was
    /// pending). Callable repeatedly: later phases (e.g. stripe writes
    /// that depend on RMW reads) submit and drain again; frontiers
    /// accumulate across drains.
    ///
    /// With an inactive [`QosConfig`] every run chains on the device's
    /// single FIFO queue ([`Device::io_run`]) — the pre-QoS schedule,
    /// bit-exact. With a split active, runs execute on per-class
    /// frontier lanes: capped classes yield to committed foreground
    /// work and stretch `1/share`×; the foreground lane runs at
    /// `1 − Σ(shares)` until every committed capped-class frontier is
    /// behind it, and at full rate after (see the module docs).
    pub fn drain(&mut self, devices: &mut [Device]) -> SimTime {
        let qos = self.qos;
        let throttled = qos.active();
        let tenancy = self.tenants.active();
        let epoch = self.epoch;
        let epoch_start = self.epoch_start;
        let fg = TrafficClass::Foreground.index();
        let mut batch_done = 0.0f64;
        for &dev in &self.touched {
            let shard = &mut self.shards[dev];
            if shard.pending.is_empty() {
                continue;
            }
            // take the queue so each completed run can recycle its
            // ticket storage into the pool; the queue Vec itself (and
            // its capacity) returns to the shard afterwards
            let mut pending = std::mem::take(&mut shard.pending);
            for run in pending.drain(..) {
                let d = &mut devices[dev];
                if shard.epoch != epoch {
                    // first commit under a NEW epoch: a shard idle at
                    // the epoch start re-seeds from the device queue
                    // tail below, exactly like a fresh private
                    // scheduler (bit-exact, `tests/prop_tenant.rs`); a
                    // shard still busy keeps its frontiers and lanes —
                    // the epochs contend
                    if epoch_start >= shard.frontier {
                        shard.base = None;
                        shard.class_busy = [0.0; N_CLASSES];
                        shard.class_lent = [0.0; N_CLASSES];
                        shard.lanes.clear();
                    }
                    shard.epoch = epoch;
                    shard.epoch_frontier = 0.0;
                }
                if shard.base.is_none() {
                    // first commit on this shard: external work ends at
                    // the device's current queue tail; every class
                    // starts from there
                    shard.base = Some(d.busy_until);
                    shard.class_frontier = [d.busy_until; N_CLASSES];
                }
                let svc = d.profile.service_time(run.size, run.op, run.access);
                let n = run.tickets.len();
                let work = n as f64 * svc;
                let ci = run.class.index();
                let end;
                if tenancy {
                    // tenant-lane path: the run schedules on its
                    // (tenant, class) frontier lane at
                    // `tenant share × class share` of the device rate —
                    // the capped-lane stretch generalized to weighted
                    // tenants. A capped class additionally yields to
                    // the SAME tenant's committed foreground lane
                    // (repair throttling semantics preserved inside
                    // each tenant); lanes never wait on OTHER tenants'
                    // lanes, so no tenant can starve another.
                    // Work-conserving borrowing lifts only the CLASS
                    // factor (the tenant weight still applies — the
                    // fairness isolation `prop_tenant.rs` pins): a
                    // capped lane whose tenant has no committed
                    // foreground backlog at the run's submit time runs
                    // at the full tenant share.
                    let class_share = qos.share(run.class);
                    let tenant_share =
                        self.tenants.share(run.tenant).clamp(0.01, 1.0);
                    let lane_base = shard.base.unwrap_or(d.busy_until);
                    let fg_floor = if ci != fg && class_share < 1.0 {
                        shard
                            .lane((run.tenant, fg))
                            .map_or(lane_base, |l| l.frontier)
                    } else {
                        lane_base
                    };
                    let borrows = qos.work_conserving
                        && ci != fg
                        && class_share < 1.0
                        && fg_floor <= run.submit_at;
                    let share = if borrows {
                        tenant_share
                    } else {
                        (tenant_share * class_share).clamp(0.01, 1.0)
                    };
                    let lane = shard.lane_entry((run.tenant, ci), lane_base);
                    let start = run.submit_at.max(lane.frontier).max(fg_floor);
                    let svc_eff = svc / share;
                    end = start + n as f64 * svc_eff;
                    for (i, &t) in run.tickets.iter().enumerate() {
                        self.completions[t] = start + (i + 1) as f64 * svc_eff;
                    }
                    lane.frontier = end;
                    lane.busy += work;
                    if borrows {
                        let static_share =
                            (tenant_share * class_share).clamp(0.01, 1.0);
                        shard.class_lent[ci] +=
                            work / static_share - work / share;
                    }
                    d.commit_run(end, n as u64, run.size, run.op);
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                } else if !throttled {
                    // pre-QoS path: one FIFO queue per device
                    let start = run.submit_at.max(d.busy_until);
                    end = d.io_run(
                        run.submit_at,
                        n as u64,
                        run.size,
                        run.op,
                        run.access,
                    );
                    for (i, &t) in run.tickets.iter().enumerate() {
                        self.completions[t] = start + (i + 1) as f64 * svc;
                    }
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                } else if qos.share(run.class) < 1.0 {
                    // capped lane: yield to committed foreground, then
                    // proceed at `share` of the device rate (virtual-
                    // time stretch on the class's own frontier).
                    // Work-conserving mode (ISSUE 10): a run with no
                    // committed foreground backlog at its submit time
                    // borrows the idle headroom and runs at full rate;
                    // any foreground commit ahead of the submission
                    // reimposes the static stretch — the reclaim bound
                    // `tests/prop_qos_conserving.rs` pins.
                    let share = qos.share(run.class);
                    let borrows = qos.work_conserving
                        && shard.class_frontier[fg] <= run.submit_at;
                    let start = run
                        .submit_at
                        .max(shard.class_frontier[ci])
                        .max(shard.class_frontier[fg]);
                    let svc_eff = if borrows { svc } else { svc / share };
                    end = start + n as f64 * svc_eff;
                    for (i, &t) in run.tickets.iter().enumerate() {
                        self.completions[t] = start + (i + 1) as f64 * svc_eff;
                    }
                    if borrows {
                        shard.class_lent[ci] += work / share - work;
                    }
                    d.commit_run(end, n as u64, run.size, run.op);
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                } else {
                    // foreground lane (uncapped classes ride it): full
                    // rate, reduced only over committed capped backlog
                    let start = run
                        .submit_at
                        .max(shard.class_frontier[ci])
                        .max(shard.class_frontier[fg]);
                    let (e, contended) =
                        contended_end(&shard.class_frontier, qos, start, work);
                    end = e;
                    if contended {
                        // spread ticket completions across the slowed
                        // span (queueing order preserved; the division
                        // first so the last ticket lands exactly on
                        // `end`)
                        let span = end - start;
                        for (i, &t) in run.tickets.iter().enumerate() {
                            self.completions[t] =
                                start + span * ((i + 1) as f64 / n as f64);
                        }
                    } else {
                        // uncontended: the exact pre-QoS arithmetic, so
                        // zero-background workloads are bit-identical
                        for (i, &t) in run.tickets.iter().enumerate() {
                            self.completions[t] = start + (i + 1) as f64 * svc;
                        }
                    }
                    d.commit_run(end, n as u64, run.size, run.op);
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                    shard.class_frontier[fg] = shard.class_frontier[fg].max(end);
                }
                shard.class_busy[ci] += work;
                shard.frontier = shard.frontier.max(end);
                shard.epoch_frontier = shard.epoch_frontier.max(end);
                self.n_runs += 1;
                batch_done = batch_done.max(end);
                // recycle the run's ticket storage for future submits
                let mut tickets = run.tickets;
                tickets.clear();
                self.ticket_pool.push(tickets);
            }
            shard.pending = pending;
        }
        batch_done
    }

    /// Completion time of a drained ticket.
    pub fn completion(&self, ticket: Ticket) -> SimTime {
        self.completions[ticket]
    }

    /// Group completion: the **max over per-device completion
    /// frontiers** (0.0 if nothing has been drained). This is what
    /// `OpGroup::wait_all` folds in instead of a serial walk. Scoped
    /// to the current epoch: on the shared cluster-wide scheduler a
    /// group only waits on its OWN submissions, never on another
    /// group's completions (un-epoched schedulers keep every shard in
    /// epoch 0, so this is the plain max-over-frontiers as before).
    pub fn wait_all(&self) -> SimTime {
        self.touched
            .iter()
            .map(|&d| &self.shards[d])
            .filter(|s| s.epoch == self.epoch)
            .fold(0.0, |t, s| t.max(s.epoch_frontier))
    }

    /// Completion frontier of one device's shard (0.0 if untouched).
    pub fn frontier(&self, device: usize) -> SimTime {
        self.shards.get(device).map_or(0.0, |s| s.frontier)
    }

    /// Completion frontier of one class on one device's shard (0.0 if
    /// the shard is untouched).
    pub fn class_frontier(&self, device: usize, class: TrafficClass) -> SimTime {
        self.shards
            .get(device)
            .map_or(0.0, |s| s.class_frontier[class.index()])
    }

    /// `(device, completion frontier)` for every shard this scheduler
    /// drained work on **during the current epoch**, in device order
    /// (diagnostics: per-device frontier tables in session reports and
    /// the ablation benches). Epoch scoping keeps one group's report
    /// free of another group's shards on the shared scheduler;
    /// un-epoched schedulers report every shard, as before.
    pub fn frontiers(&self) -> Vec<(usize, SimTime)> {
        let mut out = Vec::new();
        self.frontiers_into(&mut out);
        out
    }

    /// [`IoScheduler::frontiers`] into a caller-owned buffer (cleared
    /// first) — allocation-free once `out`'s capacity has grown to the
    /// shard count, for hot diagnostic loops (§Perf).
    pub fn frontiers_into(&self, out: &mut Vec<(usize, SimTime)>) {
        out.clear();
        for &d in &self.touched {
            let s = &self.shards[d];
            if s.epoch == self.epoch {
                out.push((d, s.epoch_frontier));
            }
        }
    }

    /// The per-class frontier table: one [`QosShardReport`] per shard
    /// this scheduler has drained work on during the current epoch, in
    /// device order. See OPERATIONS.md §Reading the per-class frontier
    /// tables. (A shard that contends across epochs reports its full
    /// lane history — `class_busy` accumulates until the shard next
    /// re-seeds idle.)
    pub fn qos_report(&self) -> Vec<QosShardReport> {
        let mut out = Vec::new();
        self.qos_report_into(&mut out);
        out
    }

    /// [`IoScheduler::qos_report`] into a caller-owned buffer (cleared
    /// first) — allocation-free once `out`'s capacity has grown to the
    /// shard count (§Perf).
    pub fn qos_report_into(&self, out: &mut Vec<QosShardReport>) {
        out.clear();
        for &d in &self.touched {
            let s = &self.shards[d];
            if s.epoch != self.epoch {
                continue;
            }
            if let Some(base) = s.base {
                out.push(Self::qos_row(d, s, base));
            }
        }
    }

    /// [`IoScheduler::qos_report`] without the epoch scope: every
    /// shard with committed work, across all sessions — the
    /// cluster-operator view. This is what
    /// [`Session::run`](crate::clovis::session::Session::run) builds
    /// the placement [`CongestionView`] from at adoption time
    /// (ISSUE 10): back-to-back sessions see every frontier at or
    /// behind the clock (zero backlog depth ⇒ placement unchanged
    /// bit-for-bit); overlapped sessions see the in-flight backlog and
    /// steer new units away from it.
    ///
    /// [`CongestionView`]: crate::mero::pool::CongestionView
    pub fn qos_report_all(&self) -> Vec<QosShardReport> {
        self.touched
            .iter()
            .filter_map(|&d| {
                let s = &self.shards[d];
                s.base.map(|base| Self::qos_row(d, s, base))
            })
            .collect()
    }

    fn qos_row(d: usize, s: &Shard, base: SimTime) -> QosShardReport {
        QosShardReport {
            device: d,
            base,
            frontier: s.frontier,
            class_busy: s.class_busy,
            class_frontier: s.class_frontier,
            lent: s.class_lent,
        }
    }

    /// The per-tenant frontier table: one [`TenantShardReport`] per
    /// shard with tenant lanes drained during the current epoch, in
    /// device order — empty while the tenant plane is inactive. See
    /// OPERATIONS.md §Reading the per-tenant frontier tables.
    pub fn tenant_report(&self) -> Vec<TenantShardReport> {
        let mut out = Vec::new();
        self.tenant_report_into(&mut out);
        out
    }

    /// [`IoScheduler::tenant_report`] into a caller-owned buffer
    /// (cleared first); the outer Vec's capacity is reused — per-row
    /// lane Vecs still allocate, but rows only exist while the tenant
    /// plane is active (§Perf).
    pub fn tenant_report_into(&self, out: &mut Vec<TenantShardReport>) {
        out.clear();
        for &d in &self.touched {
            let s = &self.shards[d];
            if s.epoch != self.epoch || s.lanes.is_empty() {
                continue;
            }
            if let Some(row) = Self::tenant_row(d, s) {
                out.push(row);
            }
        }
    }

    /// [`IoScheduler::tenant_report`] without the epoch scope: every
    /// shard with live tenant lanes, across all sessions — the
    /// cluster-operator view (`sage tenants`, `ablate_tenants`).
    pub fn tenant_report_all(&self) -> Vec<TenantShardReport> {
        self.touched
            .iter()
            .map(|&d| (d, &self.shards[d]))
            .filter(|(_, s)| !s.lanes.is_empty())
            .filter_map(|(d, s)| Self::tenant_row(d, s))
            .collect()
    }

    fn tenant_row(d: usize, s: &Shard) -> Option<TenantShardReport> {
        s.base.map(|base| TenantShardReport {
            device: d,
            base,
            lanes: s
                .lanes
                .iter()
                .map(|&((tenant, ci), l)| TenantLaneReport {
                    tenant,
                    class: TrafficClass::ALL[ci],
                    busy: l.busy,
                    frontier: l.frontier,
                })
                .collect(),
        })
    }

    /// Number of shards (distinct devices touched).
    pub fn shard_count(&self) -> usize {
        self.touched.len()
    }

    /// Device accounting calls issued so far — one per
    /// device-contiguous run (<= [`IoScheduler::ios`]).
    pub fn io_calls(&self) -> u64 {
        self.n_runs
    }

    /// Logical unit I/Os submitted so far.
    pub fn ios(&self) -> u64 {
        self.n_ios
    }

    /// Submitted-but-not-yet-drained I/Os.
    pub fn pending(&self) -> usize {
        self.touched
            .iter()
            .map(|&d| {
                self.shards[d]
                    .pending
                    .iter()
                    .map(|r| r.tickets.len())
                    .sum::<usize>()
            })
            .sum()
    }
}

/// Completion of a foreground-lane run of `work` device-seconds
/// starting at `start`, given the committed capped-class frontiers:
/// piecewise-constant integration at rate `1 − Σ(shares of capped
/// classes whose frontier is still ahead)`, floored at
/// [`MIN_FOREGROUND_RATE`]. Returns `(end, contended)`; when no capped
/// backlog overlaps, `end == start + work` computed with the exact
/// pre-QoS arithmetic (`contended == false`).
pub(crate) fn contended_end(
    frontiers: &[SimTime; N_CLASSES],
    qos: QosConfig,
    start: SimTime,
    work: f64,
) -> (SimTime, bool) {
    // at most N_CLASSES-1 capped classes: fixed buffer, no allocation
    // in the drain hot loop
    let mut caps = [(0.0f64, 0.0f64); N_CLASSES];
    let mut n_caps = 0;
    for class in TrafficClass::ALL {
        let share = qos.share(class);
        if share < 1.0 && frontiers[class.index()] > start {
            caps[n_caps] = (frontiers[class.index()], share);
            n_caps += 1;
        }
    }
    if n_caps == 0 {
        return (start + work, false);
    }
    let caps = &caps[..n_caps];
    let mut t = start;
    let mut remaining = work;
    loop {
        let mut rate = 1.0f64;
        let mut next = f64::INFINITY;
        for &(f, s) in caps {
            if f > t {
                rate -= s;
                next = next.min(f);
            }
        }
        let rate = rate.max(MIN_FOREGROUND_RATE);
        if next.is_finite() {
            let slice = (next - t) * rate;
            if slice < remaining {
                remaining -= slice;
                t = next;
                continue;
            }
        }
        return (t + remaining / rate, true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceProfile;

    fn ssd() -> Device {
        Device::new(DeviceProfile::ssd(1 << 40))
    }

    fn smr() -> Device {
        Device::new(DeviceProfile::smr(1 << 40))
    }

    #[test]
    fn devices_overlap_in_virtual_time() {
        let mut devs = vec![ssd(), ssd()];
        let mut sched = IoScheduler::new();
        let a = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        let b = sched.submit(1, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        let done = sched.drain(&mut devs);
        // both devices served concurrently: group completes when ONE
        // 1 MiB write does, not two back-to-back
        assert_eq!(sched.completion(a), sched.completion(b));
        assert_eq!(done, sched.completion(a));
        assert_eq!(sched.wait_all(), done);
        assert!(done < 2.0 * sched.completion(a));
        assert_eq!(sched.shard_count(), 2);
    }

    #[test]
    fn same_shard_serializes_and_coalesces_runs() {
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        let t0 = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        let t1 = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        let t2 = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        // one accounting call for the device-contiguous run of three
        assert_eq!(sched.io_calls(), 1);
        assert_eq!(sched.ios(), 3);
        // queueing within the run is preserved
        let svc = devs[0].profile.service_time(1 << 20, IoOp::Read, Access::Seq);
        assert!(sched.completion(t0) < sched.completion(t1));
        assert!(sched.completion(t1) < sched.completion(t2));
        assert!((sched.completion(t2) - 3.0 * svc).abs() < 1e-12);
        assert_eq!(sched.frontier(0), sched.completion(t2));
        assert_eq!(devs[0].bytes_read, 3 << 20);
    }

    #[test]
    fn run_coalescing_matches_chained_io_calls() {
        // n submissions through the scheduler == n chained io() calls
        let mut serial = ssd();
        let mut t = 0.0;
        for _ in 0..5 {
            t = serial.io(0.0, 4096, IoOp::Write, Access::Seq);
        }
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        let mut last = 0;
        for _ in 0..5 {
            last = sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        }
        sched.drain(&mut devs);
        assert!((sched.completion(last) - t).abs() < 1e-12);
        assert!((devs[0].busy_until - serial.busy_until).abs() < 1e-12);
        assert_eq!(devs[0].bytes_written, serial.bytes_written);
        assert_eq!(sched.io_calls(), 1, "one accounting call for the run");
    }

    #[test]
    fn slow_shard_does_not_drag_fast_shard() {
        // one tier-4 SMR straggler next to flash: its shard's frontier
        // is late, the flash shard's is not — and wait_all is the max
        let mut devs = vec![ssd(), smr()];
        let mut sched = IoScheduler::new();
        sched.submit(0, 0.0, 1 << 22, IoOp::Write, Access::Seq);
        sched.submit(1, 0.0, 1 << 22, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        assert!(sched.frontier(1) > 5.0 * sched.frontier(0));
        assert_eq!(sched.wait_all(), sched.frontier(1));
    }

    #[test]
    fn multi_phase_drains_accumulate_frontiers() {
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        let a = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Random);
        let t_read = sched.drain(&mut devs);
        assert_eq!(t_read, sched.completion(a));
        // phase 2 submits at the phase-1 completion (RMW dependency)
        sched.submit(0, t_read, 1 << 20, IoOp::Write, Access::Seq);
        let t_write = sched.drain(&mut devs);
        assert!(t_write > t_read);
        assert_eq!(sched.wait_all(), t_write);
        // nothing pending: an empty drain reports 0.0 and changes nothing
        assert_eq!(sched.pending(), 0);
        assert_eq!(sched.drain(&mut devs), 0.0);
        assert_eq!(sched.wait_all(), t_write);
    }

    #[test]
    fn interleaved_submissions_coalesce_per_shard() {
        // global submission order a,b,a,b: each shard still sees ONE
        // contiguous run of two
        let mut devs = vec![ssd(), ssd()];
        let mut sched = IoScheduler::new();
        sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.submit(1, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.submit(1, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        assert_eq!(sched.io_calls(), 2);
        assert_eq!(sched.ios(), 4);
    }

    #[test]
    fn execution_is_deterministic() {
        let run = || {
            let mut devs = vec![ssd(), smr(), ssd()];
            let mut sched = IoScheduler::with_qos(QosConfig::default());
            for i in 0..30u64 {
                let class = TrafficClass::ALL[(i % 3) as usize];
                sched.set_class(class);
                sched.submit(
                    (i % 3) as usize,
                    (i / 3) as f64 * 1e-4,
                    4096 * (1 + i % 4),
                    if i % 2 == 0 { IoOp::Read } else { IoOp::Write },
                    Access::Seq,
                );
            }
            sched.drain(&mut devs);
            sched.wait_all()
        };
        assert_eq!(run().to_bits(), run().to_bits());
    }

    // ------------------------------------------------------ QoS plane

    #[test]
    fn class_change_breaks_run_coalescing() {
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.set_class(TrafficClass::Repair);
        sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        assert_eq!(sched.ios(), 2);
        assert_eq!(sched.io_calls(), 2, "classes never share a run");
    }

    #[test]
    fn all_shares_at_one_take_the_pre_qos_path_bit_exactly() {
        let run = |qos: QosConfig| {
            let mut devs = vec![ssd(), smr()];
            let mut sched = IoScheduler::with_qos(qos);
            let mut tickets = Vec::new();
            for i in 0..12u64 {
                let class = TrafficClass::ALL[(i % 3) as usize];
                sched.set_class(class);
                tickets.push(sched.submit(
                    (i % 2) as usize,
                    i as f64 * 1e-5,
                    8192,
                    IoOp::Write,
                    Access::Seq,
                ));
            }
            sched.drain(&mut devs);
            let mut bits: Vec<u64> =
                tickets.iter().map(|&t| sched.completion(t).to_bits()).collect();
            bits.push(sched.wait_all().to_bits());
            bits
        };
        let cap_one =
            QosConfig { repair_share: 1.0, migration_share: 1.0, work_conserving: false };
        assert!(!cap_one.active());
        assert_eq!(run(cap_one), run(QosConfig::unlimited()));
    }

    #[test]
    fn zero_background_split_is_bit_identical_to_unthrottled() {
        let run = |qos: QosConfig| {
            let mut devs = vec![ssd(), ssd(), smr()];
            let mut sched = IoScheduler::with_qos(qos);
            let mut tickets = Vec::new();
            for i in 0..15u64 {
                tickets.push(sched.submit(
                    (i % 3) as usize,
                    (i / 3) as f64 * 1e-4,
                    4096 * (1 + i % 3),
                    if i % 2 == 0 { IoOp::Read } else { IoOp::Write },
                    Access::Seq,
                ));
            }
            sched.drain(&mut devs);
            // a second phase exercises frontier accumulation too
            let t = sched.wait_all();
            sched.submit(0, t, 1 << 16, IoOp::Write, Access::Seq);
            sched.drain(&mut devs);
            let mut bits: Vec<u64> =
                tickets.iter().map(|&t| sched.completion(t).to_bits()).collect();
            bits.push(sched.wait_all().to_bits());
            bits
        };
        assert!(QosConfig::default().active());
        assert_eq!(run(QosConfig::default()), run(QosConfig::unlimited()));
    }

    #[test]
    fn capped_class_is_stretched_and_yields_to_foreground() {
        let qos = QosConfig::default(); // repair at 0.30
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::with_qos(qos);
        // foreground commits first
        let f = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let t_fg = sched.completion(f);
        // repair submitted at 0 still waits for the committed
        // foreground frontier, then runs at 0.30 of the device rate
        sched.set_class(TrafficClass::Repair);
        let r = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        let svc = devs[0].profile.service_time(1 << 20, IoOp::Read, Access::Seq);
        let want = t_fg + svc / 0.30;
        assert!((sched.completion(r) - want).abs() < 1e-9, "stretched 1/share");
        assert_eq!(
            sched.class_frontier(0, TrafficClass::Repair),
            sched.completion(r)
        );
        assert_eq!(sched.class_frontier(0, TrafficClass::Foreground), t_fg);
    }

    #[test]
    fn foreground_slows_over_committed_repair_backlog_but_beats_fifo() {
        let qos = QosConfig::default();
        let svc_w = ssd().profile.service_time(1 << 20, IoOp::Write, Access::Seq);
        // throttled engine: repair committed first, then foreground
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::with_qos(qos);
        sched.set_class(TrafficClass::Repair);
        sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let t_repair = sched.wait_all(); // svc_w / 0.30
        sched.set_class(TrafficClass::Foreground);
        let f = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        // foreground overlaps the repair window at rate 0.70; the whole
        // write fits inside it (repair window is svc/0.3 long)
        let want = svc_w / 0.70;
        assert!(
            (sched.completion(f) - want).abs() < 1e-9,
            "got {}, want {want}",
            sched.completion(f)
        );
        // FIFO (unthrottled) would have queued it behind the repair
        let mut devs2 = vec![ssd()];
        let mut fifo = IoScheduler::new();
        fifo.set_class(TrafficClass::Repair);
        fifo.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        fifo.drain(&mut devs2);
        fifo.set_class(TrafficClass::Foreground);
        let f2 = fifo.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        fifo.drain(&mut devs2);
        assert!(
            sched.completion(f) < fifo.completion(f2),
            "the split protects foreground from the rebuild backlog"
        );
        // while the repair frontier is where the stretch put it
        assert!((t_repair - svc_w / 0.30).abs() < 1e-9);
    }

    #[test]
    fn observed_share_is_bounded_by_the_cap() {
        let qos = QosConfig::default();
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::with_qos(qos);
        sched.set_class(TrafficClass::Repair);
        for i in 0..6 {
            sched.submit(0, i as f64 * 1e-3, 1 << 18, IoOp::Read, Access::Seq);
            sched.drain(&mut devs);
        }
        let report = sched.qos_report();
        assert_eq!(report.len(), 1);
        let share = report[0].observed_share(TrafficClass::Repair);
        assert!(share > 0.0);
        assert!(
            share <= qos.share(TrafficClass::Repair) + 1e-9,
            "observed {share} exceeds the cap"
        );
        // repair-only progress: nothing deadlocks on an idle-foreground
        // shard, the frontier just stretches
        assert!(report[0].frontier > 0.0);
        assert_eq!(sched.pending(), 0);
    }

    #[test]
    fn migration_and_repair_hold_independent_capped_lanes() {
        let qos = QosConfig::default();
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::with_qos(qos);
        sched.set_class(TrafficClass::Repair);
        let r = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        sched.set_class(TrafficClass::Migration);
        let m = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        let svc = devs[0].profile.service_time(1 << 20, IoOp::Read, Access::Seq);
        // each capped class stretches on its OWN frontier (no foreground
        // committed): repair 1/0.30, migration 1/0.20 — they overlap
        assert!((sched.completion(r) - svc / 0.30).abs() < 1e-9);
        assert!((sched.completion(m) - svc / 0.20).abs() < 1e-9);
        let rep = &sched.qos_report()[0];
        assert!(rep.observed_share(TrafficClass::Repair) <= 0.30 + 1e-9);
        assert!(rep.observed_share(TrafficClass::Migration) <= 0.20 + 1e-9);
    }

    #[test]
    fn base_captures_external_queue_tail_once() {
        // work committed by an EARLIER scheduler (a previous session)
        // floors every class frontier; our own commits do not re-floor
        let mut devs = vec![ssd()];
        devs[0].io(0.0, 1 << 20, IoOp::Write, Access::Seq);
        let external = devs[0].busy_until;
        let mut sched = IoScheduler::with_qos(QosConfig::default());
        sched.set_class(TrafficClass::Repair);
        let r = sched.submit(0, 0.0, 1 << 18, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        assert!(sched.completion(r) > external, "queues behind external work");
        let rep = &sched.qos_report()[0];
        assert_eq!(rep.base, external);
        // the device queue tail advanced to our stretched frontier
        assert_eq!(devs[0].busy_until, sched.wait_all());
    }

    // ------------------------------------ work-conserving borrowing

    #[test]
    fn conserving_capped_lane_borrows_idle_foreground_headroom() {
        // repair-only shard: no committed foreground backlog, so the
        // capped lane borrows and runs at FULL device rate
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::with_qos(QosConfig::conserving());
        sched.set_class(TrafficClass::Repair);
        let r = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        let svc = devs[0].profile.service_time(1 << 20, IoOp::Read, Access::Seq);
        assert_eq!(
            sched.completion(r).to_bits(),
            svc.to_bits(),
            "borrowed run completes at the unthrottled device rate"
        );
        // the lent headroom is exactly the stretch the run avoided
        let rep = &sched.qos_report()[0];
        let want_lent = svc / 0.30 - svc;
        assert!((rep.lent_headroom(TrafficClass::Repair) - want_lent).abs() < 1e-9);
        assert_eq!(rep.lent_headroom(TrafficClass::Foreground), 0.0);
        // backlog depth reads the committed frontier against the clock
        assert_eq!(rep.backlog_depth(0.0), rep.frontier);
        assert_eq!(rep.backlog_depth(rep.frontier + 1.0), 0.0);
    }

    #[test]
    fn conserving_cap_holds_the_instant_foreground_arrives() {
        // foreground commits FIRST: a capped run submitted at or
        // before that commit sees the committed fg frontier ahead of
        // it and pays the full static stretch — bit-identical to the
        // static split's arithmetic
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::with_qos(QosConfig::conserving());
        let f = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let t_fg = sched.completion(f);
        sched.set_class(TrafficClass::Repair);
        let r = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        let svc = devs[0].profile.service_time(1 << 20, IoOp::Read, Access::Seq);
        let mut devs_s = vec![ssd()];
        let mut stat = IoScheduler::with_qos(QosConfig::default());
        let fs = stat.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        stat.drain(&mut devs_s);
        stat.set_class(TrafficClass::Repair);
        let rs = stat.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        stat.drain(&mut devs_s);
        assert_eq!(sched.completion(f).to_bits(), stat.completion(fs).to_bits());
        assert_eq!(sched.completion(r).to_bits(), stat.completion(rs).to_bits());
        assert!((sched.completion(r) - (t_fg + svc / 0.30)).abs() < 1e-9);
        // nothing was borrowed: the reclaim bound held
        let rep = &sched.qos_report()[0];
        assert_eq!(rep.lent_headroom(TrafficClass::Repair), 0.0);
        assert!(
            rep.observed_share(TrafficClass::Repair) <= 0.30 + 1e-9,
            "cap holds under contention"
        );
    }

    #[test]
    fn conserving_borrow_never_slows_foreground_or_later_static_runs() {
        let svc_w = ssd().profile.service_time(1 << 20, IoOp::Write, Access::Seq);
        // conserving engine: repair borrows at t=0 (idle foreground),
        // then foreground arrives and a second repair runs reclaimed
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::with_qos(QosConfig::conserving());
        sched.set_class(TrafficClass::Repair);
        let r1 = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        assert_eq!(sched.completion(r1).to_bits(), svc_w.to_bits(), "borrowed");
        sched.set_class(TrafficClass::Foreground);
        let f = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        // the borrowed repair window is svc long (vs svc/0.30 static),
        // so foreground at rate 0.70 clears it and finishes the rest
        // at full rate: strictly earlier than the static split's
        // svc/0.70
        let t_fg = sched.completion(f);
        assert!(t_fg < svc_w / 0.70 - 1e-12, "shorter capped window");
        // a repair submitted AFTER the foreground commit pays the full
        // static stretch from the committed foreground frontier
        sched.set_class(TrafficClass::Repair);
        let r2 = sched.submit(0, t_fg * 0.5, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let start = t_fg.max(sched.completion(r1));
        assert!(
            (sched.completion(r2) - (start + svc_w / 0.30)).abs() < 1e-9,
            "reclaimed: static stretch reapplies behind committed fg"
        );
    }

    #[test]
    fn conserving_zero_background_is_bit_identical_to_static() {
        // foreground-only traffic never touches the capped paths:
        // conserving and static produce bit-identical schedules
        let mut devs_a = vec![ssd(), smr()];
        let mut devs_b = vec![ssd(), smr()];
        let mut a = IoScheduler::with_qos(QosConfig::conserving());
        let mut b = IoScheduler::with_qos(QosConfig::default());
        let mut ta = Vec::new();
        let mut tb = Vec::new();
        for i in 0..8u64 {
            let dev = (i % 2) as usize;
            let at = i as f64 * 1e-4;
            ta.push(a.submit(dev, at, 1 << 16, IoOp::Write, Access::Seq));
            tb.push(b.submit(dev, at, 1 << 16, IoOp::Write, Access::Seq));
        }
        a.drain(&mut devs_a);
        b.drain(&mut devs_b);
        for (x, y) in ta.iter().zip(tb.iter()) {
            assert_eq!(a.completion(*x).to_bits(), b.completion(*y).to_bits());
        }
        assert_eq!(a.wait_all().to_bits(), b.wait_all().to_bits());
    }

    // --------------------------------------------- multi-tenant plane

    fn two_tenants(wa: f64, wb: f64) -> (TenantShares, TenantId, TenantId) {
        let mut shares = TenantShares::single();
        let b = shares.register(wb);
        shares.set_weight(DEFAULT_TENANT, wa);
        (shares, DEFAULT_TENANT, b)
    }

    #[test]
    fn single_tenant_table_is_inactive() {
        let shares = TenantShares::single();
        assert!(!shares.active());
        assert_eq!(shares.share(DEFAULT_TENANT), 1.0);
        assert_eq!(shares.len(), 1);
        // registration activates the plane and normalizes weights
        let (shares, a, b) = two_tenants(3.0, 1.0);
        assert!(shares.active());
        assert!((shares.share(a) - 0.75).abs() < 1e-12);
        assert!((shares.share(b) - 0.25).abs() < 1e-12);
        // unregistered ids degrade to a minimal lane, never panic
        assert!(shares.share(99) > 0.0);
        assert!(!shares.is_registered(99));
    }

    #[test]
    fn tenant_lanes_split_the_device_by_weight() {
        let (shares, a, b) = two_tenants(1.0, 1.0);
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        sched.set_tenants(shares);
        sched.set_tenant(a);
        let ta = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.set_tenant(b);
        let tb = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let svc = devs[0].profile.service_time(1 << 20, IoOp::Write, Access::Seq);
        // equal weights: each lane runs at half rate and they OVERLAP —
        // both complete at 2×svc instead of queueing svc then 2×svc
        assert!((sched.completion(ta) - 2.0 * svc).abs() < 1e-9);
        assert!((sched.completion(tb) - 2.0 * svc).abs() < 1e-9);
        // device accounting still saw both runs' bytes
        assert_eq!(devs[0].bytes_written, 2 << 20);
        // the per-tenant frontier table reports both lanes
        let rep = sched.tenant_report();
        assert_eq!(rep.len(), 1);
        assert_eq!(rep[0].lanes.len(), 2);
        assert!(rep[0].tenant_frontier(a) > rep[0].base);
        assert!(rep[0].tenant_frontier(b) > rep[0].base);
    }

    #[test]
    fn observed_tenant_share_is_bounded_by_the_weight() {
        let (shares, a, b) = two_tenants(3.0, 1.0);
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        sched.set_tenants(shares.clone());
        for i in 0..6 {
            sched.set_tenant(a);
            sched.submit(0, i as f64 * 1e-3, 1 << 18, IoOp::Read, Access::Seq);
            sched.set_tenant(b);
            sched.submit(0, i as f64 * 1e-3, 1 << 18, IoOp::Read, Access::Seq);
            sched.drain(&mut devs);
        }
        let rep = sched.tenant_report();
        assert_eq!(rep.len(), 1);
        for (tenant, want) in [(a, shares.share(a)), (b, shares.share(b))] {
            let got = rep[0].observed_share(tenant);
            assert!(got > 0.0, "tenant {tenant} starved");
            assert!(
                got <= want + 1e-9,
                "tenant {tenant} observed {got} above its share {want}"
            );
        }
    }

    #[test]
    fn capped_class_yields_to_the_same_tenants_foreground_only() {
        // tenant b's repair yields to tenant b's committed foreground,
        // but NOT to tenant a's — per-tenant throttling isolation
        let (shares, a, b) = two_tenants(1.0, 1.0);
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::with_qos(QosConfig::default());
        sched.set_tenants(shares);
        sched.set_tenant(b);
        let f = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let t_fg_b = sched.completion(f);
        // a's foreground commits later and much bigger
        sched.set_tenant(a);
        sched.submit(0, 0.0, 1 << 22, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        // b's repair: floored by b's foreground lane, then stretched at
        // tenant share × repair share = 0.5 × 0.30 = 0.15
        sched.set_tenant(b);
        sched.set_class(TrafficClass::Repair);
        let r = sched.submit(0, 0.0, 1 << 18, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        let svc = devs[0].profile.service_time(1 << 18, IoOp::Read, Access::Seq);
        let want = t_fg_b + svc / 0.15;
        assert!(
            (sched.completion(r) - want).abs() < 1e-9,
            "got {}, want {want}",
            sched.completion(r)
        );
    }

    #[test]
    fn conserving_tenant_lane_borrows_only_the_class_factor() {
        // tenant b's repair with NO committed foreground of its own
        // borrows the class cap but keeps the tenant weight: it runs
        // at the 0.5 tenant share, not 0.5 × 0.30 — per-tenant
        // fairness isolation survives borrowing
        let (shares, a, b) = two_tenants(1.0, 1.0);
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::with_qos(QosConfig::conserving());
        sched.set_tenants(shares.clone());
        // a's foreground commits (another tenant — not b's floor)
        sched.set_tenant(a);
        sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        sched.set_tenant(b);
        sched.set_class(TrafficClass::Repair);
        let r = sched.submit(0, 0.0, 1 << 18, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        let svc = devs[0].profile.service_time(1 << 18, IoOp::Read, Access::Seq);
        assert!(
            (sched.completion(r) - svc / 0.5).abs() < 1e-9,
            "borrowed lane runs at the tenant share, got {}",
            sched.completion(r)
        );
        // lent headroom records the avoided class stretch
        let rep = &sched.qos_report()[0];
        let want_lent = svc / 0.15 - svc / 0.5;
        assert!((rep.lent_headroom(TrafficClass::Repair) - want_lent).abs() < 1e-9);
        // determinism under borrowing: a bit-identical replay
        let mut devs2 = vec![ssd()];
        let mut sched2 = IoScheduler::with_qos(QosConfig::conserving());
        sched2.set_tenants(shares);
        sched2.set_tenant(a);
        sched2.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched2.drain(&mut devs2);
        sched2.set_tenant(b);
        sched2.set_class(TrafficClass::Repair);
        let r2 = sched2.submit(0, 0.0, 1 << 18, IoOp::Read, Access::Seq);
        sched2.drain(&mut devs2);
        assert_eq!(sched.completion(r).to_bits(), sched2.completion(r2).to_bits());
    }

    #[test]
    fn tenant_change_breaks_run_coalescing() {
        let (shares, a, b) = two_tenants(1.0, 1.0);
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        sched.set_tenants(shares);
        sched.set_tenant(a);
        sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.set_tenant(b);
        sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        assert_eq!(sched.ios(), 2);
        assert_eq!(sched.io_calls(), 2, "tenants never share a run");
    }

    #[test]
    fn tenant_scheduling_is_bit_deterministic() {
        let run = || {
            let (shares, a, b) = two_tenants(2.0, 1.0);
            let mut devs = vec![ssd(), smr(), ssd()];
            let mut sched = IoScheduler::with_qos(QosConfig::default());
            sched.set_tenants(shares);
            for i in 0..30u64 {
                sched.set_tenant(if i % 2 == 0 { a } else { b });
                sched.set_class(TrafficClass::ALL[(i % 3) as usize]);
                sched.submit(
                    (i % 3) as usize,
                    (i / 3) as f64 * 1e-4,
                    4096 * (1 + i % 4),
                    if i % 2 == 0 { IoOp::Read } else { IoOp::Write },
                    Access::Seq,
                );
            }
            sched.drain(&mut devs);
            sched.wait_all()
        };
        assert_eq!(run().to_bits(), run().to_bits());
    }

    // ------------------------------------------------ epoch semantics

    #[test]
    fn sequential_epochs_reproduce_fresh_schedulers_bit_exactly() {
        // ONE shared scheduler across two back-to-back "sessions" vs a
        // fresh private scheduler per session on a twin device set —
        // the core ISSUE 7 compatibility property, QoS split included
        let shared = || {
            let mut devs = vec![ssd(), smr()];
            let mut sched = IoScheduler::with_qos(QosConfig::default());
            sched.begin_epoch(0.0);
            sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
            sched.set_class(TrafficClass::Repair);
            sched.submit(1, 0.0, 1 << 18, IoOp::Read, Access::Seq);
            sched.set_class(TrafficClass::Foreground);
            sched.drain(&mut devs);
            let t1 = sched.wait_all();
            sched.begin_epoch(t1);
            sched.submit(0, t1, 1 << 20, IoOp::Read, Access::Seq);
            sched.set_class(TrafficClass::Migration);
            sched.submit(1, t1, 1 << 18, IoOp::Write, Access::Seq);
            sched.drain(&mut devs);
            let t2 = sched.wait_all();
            (t1, t2, devs[0].busy_until, devs[1].busy_until)
        };
        let private = || {
            let mut devs = vec![ssd(), smr()];
            let mut s1 = IoScheduler::with_qos(QosConfig::default());
            s1.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
            s1.set_class(TrafficClass::Repair);
            s1.submit(1, 0.0, 1 << 18, IoOp::Read, Access::Seq);
            s1.drain(&mut devs);
            let t1 = s1.wait_all();
            let mut s2 = IoScheduler::with_qos(QosConfig::default());
            s2.submit(0, t1, 1 << 20, IoOp::Read, Access::Seq);
            s2.set_class(TrafficClass::Migration);
            s2.submit(1, t1, 1 << 18, IoOp::Write, Access::Seq);
            s2.drain(&mut devs);
            let t2 = s2.wait_all();
            (t1, t2, devs[0].busy_until, devs[1].busy_until)
        };
        let (a1, a2, ad0, ad1) = shared();
        let (b1, b2, bd0, bd1) = private();
        assert_eq!(a1.to_bits(), b1.to_bits());
        assert_eq!(a2.to_bits(), b2.to_bits());
        assert_eq!(ad0.to_bits(), bd0.to_bits());
        assert_eq!(ad1.to_bits(), bd1.to_bits());
    }

    #[test]
    fn wait_all_and_frontiers_scope_to_the_current_epoch() {
        // group 1 parks a LONG write on the smr shard; group 2 (a new
        // epoch opened at time 0, i.e. concurrent) touches only the
        // ssd shard — its wait_all/frontiers must not see the smr work
        let mut devs = vec![smr(), ssd()];
        let mut sched = IoScheduler::new();
        sched.begin_epoch(0.0);
        sched.submit(0, 0.0, 1 << 22, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let t_long = sched.wait_all();
        sched.begin_epoch(0.0);
        let t = sched.submit(1, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let t_short = sched.completion(t);
        assert!(t_short < t_long);
        assert_eq!(sched.wait_all(), t_short, "scoped to own submissions");
        let fronts = sched.frontiers();
        assert_eq!(fronts, vec![(1, t_short)], "other group's shard hidden");
        // the raw per-shard view still has both (operator diagnostics)
        assert_eq!(sched.frontier(0), t_long);
    }

    #[test]
    fn overlapping_epochs_contend_on_busy_shards() {
        // epoch 2 opens at time 0 while the shard is busy until T: the
        // shard does NOT re-seed, so the new group queues behind the
        // in-flight work — contention, which private schedulers could
        // never represent
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        sched.begin_epoch(0.0);
        sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let t_first = sched.wait_all();
        sched.begin_epoch(0.0); // concurrent, not after
        let t = sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        assert!(
            sched.completion(t) > t_first,
            "queued behind the other epoch's in-flight run"
        );
        // whereas opening the epoch AFTER the frontier re-seeds: the
        // same submission pattern starts from the queue tail instead
        let mut devs2 = vec![ssd()];
        let mut sched2 = IoScheduler::new();
        sched2.begin_epoch(0.0);
        sched2.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        sched2.drain(&mut devs2);
        let t1 = sched2.wait_all();
        sched2.begin_epoch(t1);
        let u = sched2.submit(0, t1, 4096, IoOp::Write, Access::Seq);
        sched2.drain(&mut devs2);
        assert_eq!(
            sched.completion(t).to_bits(),
            sched2.completion(u).to_bits(),
            "same physics either way round: FIFO tail is the floor"
        );
    }

    // ------------------------------------- dense tables (ISSUE 8)

    #[test]
    fn dense_shard_table_matches_the_btree_oracle_bit_exactly() {
        // one submission stream replayed through the dense scheduler
        // and the preserved BTreeMap oracle: completions, wait_all and
        // frontier rows must agree to the bit, across classes, epochs
        // and a deliberately non-monotonic device order
        use crate::sim::sched_oracle::OracleScheduler;
        let mut devs_a = vec![ssd(), smr(), ssd(), smr(), ssd()];
        let mut devs_b = vec![ssd(), smr(), ssd(), smr(), ssd()];
        let mut dense = IoScheduler::with_qos(QosConfig::default());
        let mut oracle = OracleScheduler::with_qos(QosConfig::default());
        let order = [4usize, 1, 3, 0, 2, 4, 0, 1, 2, 3];
        let mut now = 0.0;
        for epoch in 0..3u64 {
            dense.begin_epoch(now);
            oracle.begin_epoch(now);
            let mut ta = Vec::new();
            let mut tb = Vec::new();
            for (i, &dev) in order.iter().enumerate() {
                let class = TrafficClass::ALL[(i + epoch as usize) % 3];
                dense.set_class(class);
                oracle.set_class(class);
                let at = now + (i / 2) as f64 * 1e-4;
                let size = 4096 * (1 + (i as u64) % 4);
                let op = if i % 2 == 0 { IoOp::Read } else { IoOp::Write };
                ta.push(dense.submit(dev, at, size, op, Access::Seq));
                tb.push(oracle.submit(dev, at, size, op, Access::Seq));
            }
            dense.drain(&mut devs_a);
            oracle.drain(&mut devs_b);
            for (&a, &b) in ta.iter().zip(&tb) {
                assert_eq!(
                    dense.completion(a).to_bits(),
                    oracle.completion(b).to_bits()
                );
            }
            assert_eq!(dense.wait_all().to_bits(), oracle.wait_all().to_bits());
            let fa = dense.frontiers();
            let fb = oracle.frontiers();
            assert_eq!(fa.len(), fb.len());
            for (x, y) in fa.iter().zip(&fb) {
                assert_eq!(x.0, y.0, "device order preserved");
                assert_eq!(x.1.to_bits(), y.1.to_bits());
            }
            now = dense.wait_all();
        }
        for (a, b) in devs_a.iter().zip(&devs_b) {
            assert_eq!(a.busy_until.to_bits(), b.busy_until.to_bits());
        }
    }

    #[test]
    fn sparse_device_ids_only_report_touched_shards() {
        // touching device 5 allocates dense slots 0..=5, but untouched
        // slots never appear in reports or counts
        let mut devs: Vec<Device> = (0..6).map(|_| ssd()).collect();
        let mut sched = IoScheduler::new();
        let t = sched.submit(5, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        assert_eq!(sched.shard_count(), 1);
        assert_eq!(sched.frontiers(), vec![(5, sched.completion(t))]);
        assert_eq!(sched.qos_report().len(), 1);
        for d in 0..5 {
            assert_eq!(sched.frontier(d), 0.0, "device {d} untouched");
        }
        // a later submission to a lower id lands in device order
        sched.submit(2, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let rows: Vec<usize> = sched.frontiers().iter().map(|f| f.0).collect();
        assert_eq!(rows, vec![2, 5], "sorted by device, not by insertion");
    }

    #[test]
    fn ticket_storage_recycles_across_epochs() {
        // begin_epoch truncates the redeemed completion table: ticket
        // ids restart from 0 each epoch (what per-session private
        // schedulers did pre-ISSUE-7) instead of growing for the life
        // of the cluster scheduler
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        sched.begin_epoch(0.0);
        let t0 = sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        assert_eq!(t0, 0);
        sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        let t_end = sched.wait_all();
        sched.begin_epoch(t_end);
        let t1 = sched.submit(0, t_end, 4096, IoOp::Write, Access::Seq);
        assert_eq!(t1, 0, "completion table recycled at epoch open");
        sched.drain(&mut devs);
        assert!(sched.completion(t1) > t_end);
        // cumulative dispatch stats survive the recycling
        assert_eq!(sched.ios(), 3);
    }

    #[test]
    fn lane_table_is_insert_order_independent() {
        // the dense sorted-Vec lane table must report lanes in (tenant,
        // class) order no matter which tenant touched the shard first —
        // the old BTreeMap's iteration order, pinned both ways round
        let lanes_for = |first_b: bool| {
            let (shares, a, b) = two_tenants(1.0, 1.0);
            let mut devs = vec![ssd()];
            let mut sched = IoScheduler::new();
            sched.set_tenants(shares);
            let order = if first_b { [b, a] } else { [a, b] };
            for &t in &order {
                sched.set_tenant(t);
                sched.submit(0, 0.0, 1 << 16, IoOp::Write, Access::Seq);
            }
            sched.drain(&mut devs);
            let rep = sched.tenant_report();
            assert_eq!(rep.len(), 1);
            rep[0]
                .lanes
                .iter()
                .map(|l| (l.tenant, l.class.index()))
                .collect::<Vec<_>>()
        };
        let ab = lanes_for(false);
        let ba = lanes_for(true);
        assert_eq!(ab, ba, "report order independent of insertion order");
        let mut sorted = ab.clone();
        sorted.sort_unstable();
        assert_eq!(ab, sorted, "(tenant, class) order");
    }

    #[test]
    fn report_into_variants_match_and_reuse_buffers() {
        let mut devs = vec![ssd(), smr()];
        let mut sched = IoScheduler::with_qos(QosConfig::default());
        sched.submit(0, 0.0, 1 << 18, IoOp::Write, Access::Seq);
        sched.set_class(TrafficClass::Repair);
        sched.submit(1, 0.0, 1 << 18, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        let mut fronts = Vec::new();
        let mut qos = Vec::new();
        for _ in 0..2 {
            // second pass reuses the buffers (cleared, capacity kept)
            sched.frontiers_into(&mut fronts);
            sched.qos_report_into(&mut qos);
        }
        assert_eq!(fronts, sched.frontiers());
        assert_eq!(qos.len(), sched.qos_report().len());
        for (a, b) in qos.iter().zip(sched.qos_report().iter()) {
            assert_eq!(a.device, b.device);
            assert_eq!(a.frontier.to_bits(), b.frontier.to_bits());
        }
    }

    #[test]
    fn epoch_counters_scope_dispatch_stats() {
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        sched.begin_epoch(0.0);
        for _ in 0..3 {
            sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        }
        sched.drain(&mut devs);
        assert_eq!(sched.epoch_ios(), 3);
        assert_eq!(sched.epoch_io_calls(), 1);
        let t = sched.wait_all();
        sched.begin_epoch(t);
        assert_eq!(sched.epoch_ios(), 0);
        assert_eq!(sched.epoch_io_calls(), 0);
        sched.submit(0, t, 4096, IoOp::Read, Access::Seq);
        sched.submit(0, t, 8192, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        assert_eq!(sched.epoch_ios(), 2);
        assert_eq!(sched.epoch_io_calls(), 2);
        // cumulative counters keep the cluster-wide totals
        assert_eq!(sched.ios(), 5);
        assert_eq!(sched.io_calls(), 3);
    }
}
