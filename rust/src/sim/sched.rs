//! Sharded asynchronous op execution: per-device submission queues
//! with completion frontiers (the ISSUE 2 tentpole; ARCHITECTURE.md
//! §Sharded scheduler).
//!
//! SAGE absorbs Exascale I/O by letting many devices service one
//! logical operation concurrently (§3.1–§3.2 of the paper: multi-tier
//! enclosures, SNS striping). The [`IoScheduler`] is the simulation's
//! expression of that: every [`Device`] is an independent virtual-time
//! server with its own **shard** — a submission queue plus a
//! *completion frontier* (the virtual time its queue runs dry). A
//! batch of unit I/Os is dispatched to home-device shards in one pass;
//! draining the shards advances each device independently, so units on
//! different devices overlap in virtual time and a degraded/slow
//! device only delays the requests that actually queue on it. The
//! batch completes at the **max over per-device frontiers** — not at a
//! serial fold over units (`mero::sns_serial` preserves the fold as
//! the differential oracle; `tests/prop_sched.rs` checks sharded
//! completion <= serial completion on every sampled geometry).
//!
//! §Perf: submissions to one shard that share a timestamp, size and
//! access pattern coalesce into a **device-contiguous run**, accounted
//! with ONE [`Device::io_run`] call instead of one [`Device::io`] call
//! per unit — the ROADMAP "batch the virtual-time device accounting"
//! item. Coalescing never changes virtual time: a run of `n` equal
//! I/Os queued back-to-back completes exactly when `n` chained `io()`
//! calls would.

use std::collections::BTreeMap;

use super::clock::SimTime;
use super::device::{Access, Device, IoOp};

/// Handle for one submitted I/O; redeem with
/// [`IoScheduler::completion`] after the next [`IoScheduler::drain`].
pub type Ticket = usize;

/// A device-contiguous run: consecutive submissions to one shard with
/// identical timestamp/size/op/access, accounted as one `io_run` call.
#[derive(Debug)]
struct Run {
    submit_at: SimTime,
    size: u64,
    op: IoOp,
    access: Access,
    tickets: Vec<Ticket>,
}

/// One device's slice of the scheduler: pending runs + the virtual
/// time up to which the device's queue has been driven.
#[derive(Debug, Default)]
struct Shard {
    pending: Vec<Run>,
    frontier: SimTime,
}

/// The sharded op-execution scheduler. One instance serves one op
/// group (or one self-contained store operation): submissions queue on
/// per-device shards, [`IoScheduler::drain`] executes them against the
/// devices, [`IoScheduler::wait_all`] is the group completion.
#[derive(Debug, Default)]
pub struct IoScheduler {
    /// Per-device shards, keyed by device id (deterministic order).
    shards: BTreeMap<usize, Shard>,
    /// Completion time per ticket (valid after the draining pass).
    completions: Vec<SimTime>,
    /// Device accounting calls issued (one per device-contiguous run).
    n_runs: u64,
    /// Logical I/Os submitted.
    n_ios: u64,
}

impl IoScheduler {
    /// Empty scheduler.
    pub fn new() -> Self {
        IoScheduler::default()
    }

    /// Queue one unit I/O on `device`'s shard at virtual time
    /// `submit_at`. Returns a [`Ticket`] redeemable for the completion
    /// time after the next [`IoScheduler::drain`]. Consecutive
    /// submissions to the same shard with identical parameters
    /// coalesce into one device-contiguous run (§Perf).
    pub fn submit(
        &mut self,
        device: usize,
        submit_at: SimTime,
        size: u64,
        op: IoOp,
        access: Access,
    ) -> Ticket {
        let ticket = self.completions.len();
        // placeholder until drained; never observed by correct callers
        self.completions.push(submit_at);
        self.n_ios += 1;
        let shard = self.shards.entry(device).or_default();
        if let Some(run) = shard.pending.last_mut() {
            if run.submit_at == submit_at
                && run.size == size
                && run.op == op
                && run.access == access
            {
                run.tickets.push(ticket);
                return ticket;
            }
        }
        shard.pending.push(Run {
            submit_at,
            size,
            op,
            access,
            tickets: vec![ticket],
        });
        ticket
    }

    /// Execute every pending run against its device, advancing each
    /// shard's completion frontier independently. Returns the max
    /// completion time of the *drained* batch (0.0 if nothing was
    /// pending). Callable repeatedly: later phases (e.g. stripe writes
    /// that depend on RMW reads) submit and drain again; frontiers
    /// accumulate across drains.
    pub fn drain(&mut self, devices: &mut [Device]) -> SimTime {
        let mut batch_done = 0.0f64;
        for (&dev, shard) in self.shards.iter_mut() {
            for run in shard.pending.drain(..) {
                let d = &mut devices[dev];
                let svc = d.profile.service_time(run.size, run.op, run.access);
                let start = run.submit_at.max(d.busy_until);
                let end = d.io_run(
                    run.submit_at,
                    run.tickets.len() as u64,
                    run.size,
                    run.op,
                    run.access,
                );
                for (i, &t) in run.tickets.iter().enumerate() {
                    self.completions[t] = start + (i + 1) as f64 * svc;
                }
                shard.frontier = shard.frontier.max(end);
                self.n_runs += 1;
                batch_done = batch_done.max(end);
            }
        }
        batch_done
    }

    /// Completion time of a drained ticket.
    pub fn completion(&self, ticket: Ticket) -> SimTime {
        self.completions[ticket]
    }

    /// Group completion: the **max over per-device completion
    /// frontiers** (0.0 if nothing has been drained). This is what
    /// `OpGroup::wait_all` folds in instead of a serial walk.
    pub fn wait_all(&self) -> SimTime {
        self.shards.values().fold(0.0, |t, s| t.max(s.frontier))
    }

    /// Completion frontier of one device's shard (0.0 if untouched).
    pub fn frontier(&self, device: usize) -> SimTime {
        self.shards.get(&device).map_or(0.0, |s| s.frontier)
    }

    /// `(device, completion frontier)` for every shard this scheduler
    /// touched, in device order (diagnostics: per-device frontier
    /// tables in session reports and the ablation benches).
    pub fn frontiers(&self) -> Vec<(usize, SimTime)> {
        self.shards.iter().map(|(&d, s)| (d, s.frontier)).collect()
    }

    /// Number of shards (distinct devices touched).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Device accounting calls issued so far — one per
    /// device-contiguous run (<= [`IoScheduler::ios`]).
    pub fn io_calls(&self) -> u64 {
        self.n_runs
    }

    /// Logical unit I/Os submitted so far.
    pub fn ios(&self) -> u64 {
        self.n_ios
    }

    /// Submitted-but-not-yet-drained I/Os.
    pub fn pending(&self) -> usize {
        self.shards
            .values()
            .map(|s| s.pending.iter().map(|r| r.tickets.len()).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceProfile;

    fn ssd() -> Device {
        Device::new(DeviceProfile::ssd(1 << 40))
    }

    fn smr() -> Device {
        Device::new(DeviceProfile::smr(1 << 40))
    }

    #[test]
    fn devices_overlap_in_virtual_time() {
        let mut devs = vec![ssd(), ssd()];
        let mut sched = IoScheduler::new();
        let a = sched.submit(0, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        let b = sched.submit(1, 0.0, 1 << 20, IoOp::Write, Access::Seq);
        let done = sched.drain(&mut devs);
        // both devices served concurrently: group completes when ONE
        // 1 MiB write does, not two back-to-back
        assert_eq!(sched.completion(a), sched.completion(b));
        assert_eq!(done, sched.completion(a));
        assert_eq!(sched.wait_all(), done);
        assert!(done < 2.0 * sched.completion(a));
        assert_eq!(sched.shard_count(), 2);
    }

    #[test]
    fn same_shard_serializes_and_coalesces_runs() {
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        let t0 = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        let t1 = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        let t2 = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        sched.drain(&mut devs);
        // one accounting call for the device-contiguous run of three
        assert_eq!(sched.io_calls(), 1);
        assert_eq!(sched.ios(), 3);
        // queueing within the run is preserved
        let svc = devs[0].profile.service_time(1 << 20, IoOp::Read, Access::Seq);
        assert!(sched.completion(t0) < sched.completion(t1));
        assert!(sched.completion(t1) < sched.completion(t2));
        assert!((sched.completion(t2) - 3.0 * svc).abs() < 1e-12);
        assert_eq!(sched.frontier(0), sched.completion(t2));
        assert_eq!(devs[0].bytes_read, 3 << 20);
    }

    #[test]
    fn run_coalescing_matches_chained_io_calls() {
        // n submissions through the scheduler == n chained io() calls
        let mut serial = ssd();
        let mut t = 0.0;
        for _ in 0..5 {
            t = serial.io(0.0, 4096, IoOp::Write, Access::Seq);
        }
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        let mut last = 0;
        for _ in 0..5 {
            last = sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        }
        sched.drain(&mut devs);
        assert!((sched.completion(last) - t).abs() < 1e-12);
        assert!((devs[0].busy_until - serial.busy_until).abs() < 1e-12);
        assert_eq!(devs[0].bytes_written, serial.bytes_written);
        assert_eq!(sched.io_calls(), 1, "one accounting call for the run");
    }

    #[test]
    fn slow_shard_does_not_drag_fast_shard() {
        // one tier-4 SMR straggler next to flash: its shard's frontier
        // is late, the flash shard's is not — and wait_all is the max
        let mut devs = vec![ssd(), smr()];
        let mut sched = IoScheduler::new();
        sched.submit(0, 0.0, 1 << 22, IoOp::Write, Access::Seq);
        sched.submit(1, 0.0, 1 << 22, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        assert!(sched.frontier(1) > 5.0 * sched.frontier(0));
        assert_eq!(sched.wait_all(), sched.frontier(1));
    }

    #[test]
    fn multi_phase_drains_accumulate_frontiers() {
        let mut devs = vec![ssd()];
        let mut sched = IoScheduler::new();
        let a = sched.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Random);
        let t_read = sched.drain(&mut devs);
        assert_eq!(t_read, sched.completion(a));
        // phase 2 submits at the phase-1 completion (RMW dependency)
        sched.submit(0, t_read, 1 << 20, IoOp::Write, Access::Seq);
        let t_write = sched.drain(&mut devs);
        assert!(t_write > t_read);
        assert_eq!(sched.wait_all(), t_write);
        // nothing pending: an empty drain reports 0.0 and changes nothing
        assert_eq!(sched.pending(), 0);
        assert_eq!(sched.drain(&mut devs), 0.0);
        assert_eq!(sched.wait_all(), t_write);
    }

    #[test]
    fn interleaved_submissions_coalesce_per_shard() {
        // global submission order a,b,a,b: each shard still sees ONE
        // contiguous run of two
        let mut devs = vec![ssd(), ssd()];
        let mut sched = IoScheduler::new();
        sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.submit(1, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.submit(1, 0.0, 4096, IoOp::Write, Access::Seq);
        sched.drain(&mut devs);
        assert_eq!(sched.io_calls(), 2);
        assert_eq!(sched.ios(), 4);
    }

    #[test]
    fn execution_is_deterministic() {
        let run = || {
            let mut devs = vec![ssd(), smr(), ssd()];
            let mut sched = IoScheduler::new();
            for i in 0..30u64 {
                sched.submit(
                    (i % 3) as usize,
                    (i / 3) as f64 * 1e-4,
                    4096 * (1 + i % 4),
                    if i % 2 == 0 { IoOp::Read } else { IoOp::Write },
                    Access::Seq,
                );
            }
            sched.drain(&mut devs);
            sched.wait_all()
        };
        assert_eq!(run().to_bits(), run().to_bits());
    }
}
