//! The **preserved static-throttle QoS scheduler** — the ISSUE 10
//! work-conserving overhaul's differential oracle.
//!
//! [`StaticQosScheduler`] is the pre-ISSUE-10 [`IoScheduler`] QoS
//! plane kept verbatim: capped classes ALWAYS stretch `1/share`× on
//! their own frontier, even on a shard whose foreground lane is idle —
//! the PR-5 static throttle that deliberately leaves `1 − share`
//! headroom unused. The work-conserving scheduler borrows that
//! headroom; this oracle is the fixed point it is measured against.
//! `tests/prop_qos_conserving.rs` replays identical submission
//! streams through both and pins the ROADMAP-stated oracle:
//! work-conserving completion is **never later** than static
//! completion for ANY class on any sampled geometry, and a static
//! `IoScheduler` (`work_conserving == false`) reproduces this oracle
//! bit-for-bit.
//!
//! Follows the `mero::sns_serial` / `mero::sns_baseline` /
//! `sim::sched_oracle` house pattern: oracles are kept, not deleted,
//! and frozen under the `sage lint` `oracle-freeze` CRC rule.
//!
//! [`IoScheduler`]: crate::sim::sched::IoScheduler

use std::collections::BTreeMap;

use super::clock::SimTime;
use super::device::{Access, Device, IoOp};
use super::sched::{
    contended_end, QosConfig, TenantId, TenantShares, Ticket, TrafficClass,
    DEFAULT_TENANT, N_CLASSES,
};

/// One `(tenant, class)` frontier lane (static-throttle layout).
#[derive(Debug, Clone, Copy)]
struct TenantLane {
    frontier: SimTime,
    busy: f64,
}

/// A device-contiguous run (static-throttle layout).
#[derive(Debug)]
struct Run {
    submit_at: SimTime,
    size: u64,
    op: IoOp,
    access: Access,
    class: TrafficClass,
    tenant: TenantId,
    tickets: Vec<Ticket>,
}

/// One device's shard (static-throttle layout).
#[derive(Debug, Default)]
struct Shard {
    pending: Vec<Run>,
    frontier: SimTime,
    base: Option<SimTime>,
    class_frontier: [SimTime; N_CLASSES],
    class_busy: [f64; N_CLASSES],
    epoch: u64,
    epoch_frontier: SimTime,
    lanes: BTreeMap<(TenantId, usize), TenantLane>,
}

/// The preserved static-throttle QoS scheduler (see module docs).
/// API subset of [`IoScheduler`](crate::sim::sched::IoScheduler) — the
/// methods the work-conserving differential suite replays through.
#[derive(Debug)]
pub struct StaticQosScheduler {
    shards: BTreeMap<usize, Shard>,
    completions: Vec<SimTime>,
    qos: QosConfig,
    class: TrafficClass,
    tenant: TenantId,
    tenants: TenantShares,
    epoch: u64,
    epoch_start: SimTime,
}

impl Default for StaticQosScheduler {
    fn default() -> Self {
        StaticQosScheduler::with_qos(QosConfig::unlimited())
    }
}

impl StaticQosScheduler {
    /// Empty oracle with no bandwidth split (pre-QoS semantics).
    pub fn new() -> Self {
        StaticQosScheduler::default()
    }

    /// Empty oracle enforcing `qos` on every shard under the STATIC
    /// throttle semantics, whatever `qos.work_conserving` says — the
    /// flag is ignored here by design: this file IS the static
    /// behavior.
    pub fn with_qos(qos: QosConfig) -> Self {
        StaticQosScheduler {
            shards: BTreeMap::new(),
            completions: Vec::new(),
            qos,
            class: TrafficClass::Foreground,
            tenant: DEFAULT_TENANT,
            tenants: TenantShares::single(),
            epoch: 0,
            epoch_start: 0.0,
        }
    }

    /// Replace the tenant table (applies to subsequent drains).
    pub fn set_tenants(&mut self, tenants: TenantShares) {
        self.tenants = tenants;
    }

    /// Set the tenant stamped on subsequent submissions.
    pub fn set_tenant(&mut self, tenant: TenantId) -> TenantId {
        std::mem::replace(&mut self.tenant, tenant)
    }

    /// Set the class stamped on subsequent submissions.
    pub fn set_class(&mut self, class: TrafficClass) -> TrafficClass {
        std::mem::replace(&mut self.class, class)
    }

    /// Open a new scheduling epoch at `now` (the pre-overhaul
    /// semantics: the completion table keeps growing across epochs).
    pub fn begin_epoch(&mut self, now: SimTime) -> u64 {
        self.epoch += 1;
        self.epoch_start = now;
        self.epoch
    }

    /// Queue one unit I/O — byte-for-byte the static scheduler's
    /// `submit`.
    pub fn submit(
        &mut self,
        device: usize,
        submit_at: SimTime,
        size: u64,
        op: IoOp,
        access: Access,
    ) -> Ticket {
        let ticket = self.completions.len();
        self.completions.push(submit_at);
        let class = self.class;
        let tenant = self.tenant;
        let shard = self.shards.entry(device).or_default();
        if let Some(run) = shard.pending.last_mut() {
            if run.submit_at == submit_at
                && run.size == size
                && run.op == op
                && run.access == access
                && run.class == class
                && run.tenant == tenant
            {
                run.tickets.push(ticket);
                return ticket;
            }
        }
        shard.pending.push(Run {
            submit_at,
            size,
            op,
            access,
            class,
            tenant,
            tickets: vec![ticket],
        });
        ticket
    }

    /// Execute every pending run — byte-for-byte the STATIC drain: a
    /// capped lane always yields to committed foreground and then
    /// stretches `1/share`×, foreground integrates `1 − Σ(shares)`
    /// over committed capped backlog, idle-foreground headroom is
    /// never lent.
    pub fn drain(&mut self, devices: &mut [Device]) -> SimTime {
        let qos = self.qos;
        let throttled = qos.active();
        let tenancy = self.tenants.active();
        let epoch = self.epoch;
        let epoch_start = self.epoch_start;
        let fg = TrafficClass::Foreground.index();
        let mut batch_done = 0.0f64;
        for (&dev, shard) in self.shards.iter_mut() {
            for run in std::mem::take(&mut shard.pending) {
                let d = &mut devices[dev];
                if shard.epoch != epoch {
                    if epoch_start >= shard.frontier {
                        shard.base = None;
                        shard.class_busy = [0.0; N_CLASSES];
                        shard.lanes.clear();
                    }
                    shard.epoch = epoch;
                    shard.epoch_frontier = 0.0;
                }
                if shard.base.is_none() {
                    shard.base = Some(d.busy_until);
                    shard.class_frontier = [d.busy_until; N_CLASSES];
                }
                let svc = d.profile.service_time(run.size, run.op, run.access);
                let n = run.tickets.len();
                let work = n as f64 * svc;
                let ci = run.class.index();
                let end;
                if tenancy {
                    let share = (self.tenants.share(run.tenant)
                        * qos.share(run.class))
                    .clamp(0.01, 1.0);
                    let lane_base = shard.base.unwrap_or(d.busy_until);
                    let fg_floor = if ci != fg && qos.share(run.class) < 1.0 {
                        shard
                            .lanes
                            .get(&(run.tenant, fg))
                            .map_or(lane_base, |l| l.frontier)
                    } else {
                        lane_base
                    };
                    let lane = shard
                        .lanes
                        .entry((run.tenant, ci))
                        .or_insert(TenantLane { frontier: lane_base, busy: 0.0 });
                    let start = run.submit_at.max(lane.frontier).max(fg_floor);
                    let svc_eff = svc / share;
                    end = start + n as f64 * svc_eff;
                    for (i, &t) in run.tickets.iter().enumerate() {
                        self.completions[t] = start + (i + 1) as f64 * svc_eff;
                    }
                    lane.frontier = end;
                    lane.busy += work;
                    d.commit_run(end, n as u64, run.size, run.op);
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                } else if !throttled {
                    let start = run.submit_at.max(d.busy_until);
                    end = d.io_run(
                        run.submit_at,
                        n as u64,
                        run.size,
                        run.op,
                        run.access,
                    );
                    for (i, &t) in run.tickets.iter().enumerate() {
                        self.completions[t] = start + (i + 1) as f64 * svc;
                    }
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                } else if qos.share(run.class) < 1.0 {
                    let share = qos.share(run.class);
                    let start = run
                        .submit_at
                        .max(shard.class_frontier[ci])
                        .max(shard.class_frontier[fg]);
                    let svc_eff = svc / share;
                    end = start + n as f64 * svc_eff;
                    for (i, &t) in run.tickets.iter().enumerate() {
                        self.completions[t] = start + (i + 1) as f64 * svc_eff;
                    }
                    d.commit_run(end, n as u64, run.size, run.op);
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                } else {
                    let start = run
                        .submit_at
                        .max(shard.class_frontier[ci])
                        .max(shard.class_frontier[fg]);
                    let (e, contended) =
                        contended_end(&shard.class_frontier, qos, start, work);
                    end = e;
                    if contended {
                        let span = end - start;
                        for (i, &t) in run.tickets.iter().enumerate() {
                            self.completions[t] =
                                start + span * ((i + 1) as f64 / n as f64);
                        }
                    } else {
                        for (i, &t) in run.tickets.iter().enumerate() {
                            self.completions[t] = start + (i + 1) as f64 * svc;
                        }
                    }
                    d.commit_run(end, n as u64, run.size, run.op);
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                    shard.class_frontier[fg] = shard.class_frontier[fg].max(end);
                }
                shard.class_busy[ci] += work;
                shard.frontier = shard.frontier.max(end);
                shard.epoch_frontier = shard.epoch_frontier.max(end);
                batch_done = batch_done.max(end);
            }
        }
        batch_done
    }

    /// Completion time of a drained ticket.
    pub fn completion(&self, ticket: Ticket) -> SimTime {
        self.completions[ticket]
    }

    /// Max epoch frontier over the current epoch's shards.
    pub fn wait_all(&self) -> SimTime {
        self.shards
            .values()
            .filter(|s| s.epoch == self.epoch)
            .fold(0.0, |t, s| t.max(s.epoch_frontier))
    }

    /// `(device, epoch frontier)` rows in BTreeMap (device) order.
    pub fn frontiers(&self) -> Vec<(usize, SimTime)> {
        self.shards
            .iter()
            .filter(|(_, s)| s.epoch == self.epoch)
            .map(|(&d, s)| (d, s.epoch_frontier))
            .collect()
    }

    /// Completion frontier of one class on one device's shard (0.0 if
    /// the shard is untouched) — what the differential suite compares
    /// per class.
    pub fn class_frontier(&self, device: usize, class: TrafficClass) -> SimTime {
        self.shards
            .get(&device)
            .map_or(0.0, |s| s.class_frontier[class.index()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceProfile;

    #[test]
    fn static_oracle_keeps_the_idle_foreground_stretch() {
        // the defining static behavior: a repair-only shard still
        // stretches 1/share — headroom is never lent
        let mut devs = vec![Device::new(DeviceProfile::ssd(1 << 40))];
        let mut o = StaticQosScheduler::with_qos(QosConfig::default());
        o.set_class(TrafficClass::Repair);
        let r = o.submit(0, 0.0, 1 << 20, IoOp::Read, Access::Seq);
        o.drain(&mut devs);
        let svc = devs[0].profile.service_time(1 << 20, IoOp::Read, Access::Seq);
        assert!((o.completion(r) - svc / 0.30).abs() < 1e-9);
        assert_eq!(o.wait_all(), o.completion(r));
        assert_eq!(o.frontiers(), vec![(0, o.completion(r))]);
        assert_eq!(o.class_frontier(0, TrafficClass::Repair), o.completion(r));
    }
}
