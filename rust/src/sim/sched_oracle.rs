//! The **preserved BTreeMap scheduler core** — the ISSUE 8 sim-core
//! overhaul's differential oracle.
//!
//! [`OracleScheduler`] is the pre-overhaul [`IoScheduler`] interior
//! kept verbatim: `BTreeMap<usize, Shard>` shards, per-shard
//! `BTreeMap<(TenantId, usize), TenantLane>` lanes, a fresh `tickets`
//! Vec per run and a monotonically growing completion table. The drain
//! arithmetic is shared with the live scheduler (same
//! `contended_end`, same per-path formulas), so any divergence
//! between the two is a bug in the dense *representation*, never in
//! the physics.
//!
//! Used by the `sched.rs` insert-order-independence tests and by
//! `benches/ablate_simcore.rs`, which replays one submission stream
//! through both schedulers, asserts bit-identical completions and
//! frontiers, and measures the wall-clock gap (the dense tables are
//! the thing being ablated). Follows the `mero::sns_serial` /
//! `mero::sns_baseline` house pattern: oracles are kept, not deleted.
//!
//! [`IoScheduler`]: crate::sim::sched::IoScheduler

use std::collections::BTreeMap;

use super::clock::SimTime;
use super::device::{Access, Device, IoOp};
use super::sched::{
    contended_end, QosConfig, TenantId, TenantShares, Ticket, TrafficClass,
    DEFAULT_TENANT, N_CLASSES,
};

/// One `(tenant, class)` frontier lane (pre-overhaul layout).
#[derive(Debug, Clone, Copy)]
struct TenantLane {
    frontier: SimTime,
    busy: f64,
}

/// A device-contiguous run (pre-overhaul layout: owns a fresh ticket
/// Vec per run).
#[derive(Debug)]
struct Run {
    submit_at: SimTime,
    size: u64,
    op: IoOp,
    access: Access,
    class: TrafficClass,
    tenant: TenantId,
    tickets: Vec<Ticket>,
}

/// One device's shard (pre-overhaul layout: BTreeMap lanes).
#[derive(Debug, Default)]
struct Shard {
    pending: Vec<Run>,
    frontier: SimTime,
    base: Option<SimTime>,
    class_frontier: [SimTime; N_CLASSES],
    class_busy: [f64; N_CLASSES],
    epoch: u64,
    epoch_frontier: SimTime,
    lanes: BTreeMap<(TenantId, usize), TenantLane>,
}

/// The preserved BTreeMap-backed scheduler core (see module docs).
/// API subset of [`IoScheduler`](crate::sim::sched::IoScheduler) — the
/// methods the differential tests and `ablate_simcore` replay through.
#[derive(Debug)]
pub struct OracleScheduler {
    shards: BTreeMap<usize, Shard>,
    completions: Vec<SimTime>,
    qos: QosConfig,
    class: TrafficClass,
    tenant: TenantId,
    tenants: TenantShares,
    epoch: u64,
    epoch_start: SimTime,
}

impl Default for OracleScheduler {
    fn default() -> Self {
        OracleScheduler::with_qos(QosConfig::unlimited())
    }
}

impl OracleScheduler {
    /// Empty oracle with no bandwidth split (pre-QoS semantics).
    pub fn new() -> Self {
        OracleScheduler::default()
    }

    /// Empty oracle enforcing `qos` on every shard.
    pub fn with_qos(qos: QosConfig) -> Self {
        OracleScheduler {
            shards: BTreeMap::new(),
            completions: Vec::new(),
            qos,
            class: TrafficClass::Foreground,
            tenant: DEFAULT_TENANT,
            tenants: TenantShares::single(),
            epoch: 0,
            epoch_start: 0.0,
        }
    }

    /// Replace the tenant table (applies to subsequent drains).
    pub fn set_tenants(&mut self, tenants: TenantShares) {
        self.tenants = tenants;
    }

    /// Set the tenant stamped on subsequent submissions.
    pub fn set_tenant(&mut self, tenant: TenantId) -> TenantId {
        std::mem::replace(&mut self.tenant, tenant)
    }

    /// Set the class stamped on subsequent submissions.
    pub fn set_class(&mut self, class: TrafficClass) -> TrafficClass {
        std::mem::replace(&mut self.class, class)
    }

    /// Open a new scheduling epoch at `now` (the pre-overhaul
    /// semantics: the completion table keeps growing across epochs).
    pub fn begin_epoch(&mut self, now: SimTime) -> u64 {
        self.epoch += 1;
        self.epoch_start = now;
        self.epoch
    }

    /// Queue one unit I/O — byte-for-byte the pre-overhaul `submit`.
    pub fn submit(
        &mut self,
        device: usize,
        submit_at: SimTime,
        size: u64,
        op: IoOp,
        access: Access,
    ) -> Ticket {
        let ticket = self.completions.len();
        self.completions.push(submit_at);
        let class = self.class;
        let tenant = self.tenant;
        let shard = self.shards.entry(device).or_default();
        if let Some(run) = shard.pending.last_mut() {
            if run.submit_at == submit_at
                && run.size == size
                && run.op == op
                && run.access == access
                && run.class == class
                && run.tenant == tenant
            {
                run.tickets.push(ticket);
                return ticket;
            }
        }
        shard.pending.push(Run {
            submit_at,
            size,
            op,
            access,
            class,
            tenant,
            tickets: vec![ticket],
        });
        ticket
    }

    /// Execute every pending run — byte-for-byte the pre-overhaul
    /// `drain` (BTreeMap iteration order, fresh allocations and all).
    pub fn drain(&mut self, devices: &mut [Device]) -> SimTime {
        let qos = self.qos;
        let throttled = qos.active();
        let tenancy = self.tenants.active();
        let epoch = self.epoch;
        let epoch_start = self.epoch_start;
        let fg = TrafficClass::Foreground.index();
        let mut batch_done = 0.0f64;
        for (&dev, shard) in self.shards.iter_mut() {
            for run in std::mem::take(&mut shard.pending) {
                let d = &mut devices[dev];
                if shard.epoch != epoch {
                    if epoch_start >= shard.frontier {
                        shard.base = None;
                        shard.class_busy = [0.0; N_CLASSES];
                        shard.lanes.clear();
                    }
                    shard.epoch = epoch;
                    shard.epoch_frontier = 0.0;
                }
                if shard.base.is_none() {
                    shard.base = Some(d.busy_until);
                    shard.class_frontier = [d.busy_until; N_CLASSES];
                }
                let svc = d.profile.service_time(run.size, run.op, run.access);
                let n = run.tickets.len();
                let work = n as f64 * svc;
                let ci = run.class.index();
                let end;
                if tenancy {
                    let share = (self.tenants.share(run.tenant)
                        * qos.share(run.class))
                    .clamp(0.01, 1.0);
                    let lane_base = shard.base.unwrap_or(d.busy_until);
                    let fg_floor = if ci != fg && qos.share(run.class) < 1.0 {
                        shard
                            .lanes
                            .get(&(run.tenant, fg))
                            .map_or(lane_base, |l| l.frontier)
                    } else {
                        lane_base
                    };
                    let lane = shard
                        .lanes
                        .entry((run.tenant, ci))
                        .or_insert(TenantLane { frontier: lane_base, busy: 0.0 });
                    let start = run.submit_at.max(lane.frontier).max(fg_floor);
                    let svc_eff = svc / share;
                    end = start + n as f64 * svc_eff;
                    for (i, &t) in run.tickets.iter().enumerate() {
                        self.completions[t] = start + (i + 1) as f64 * svc_eff;
                    }
                    lane.frontier = end;
                    lane.busy += work;
                    d.commit_run(end, n as u64, run.size, run.op);
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                } else if !throttled {
                    let start = run.submit_at.max(d.busy_until);
                    end = d.io_run(
                        run.submit_at,
                        n as u64,
                        run.size,
                        run.op,
                        run.access,
                    );
                    for (i, &t) in run.tickets.iter().enumerate() {
                        self.completions[t] = start + (i + 1) as f64 * svc;
                    }
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                } else if qos.share(run.class) < 1.0 {
                    let share = qos.share(run.class);
                    let start = run
                        .submit_at
                        .max(shard.class_frontier[ci])
                        .max(shard.class_frontier[fg]);
                    let svc_eff = svc / share;
                    end = start + n as f64 * svc_eff;
                    for (i, &t) in run.tickets.iter().enumerate() {
                        self.completions[t] = start + (i + 1) as f64 * svc_eff;
                    }
                    d.commit_run(end, n as u64, run.size, run.op);
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                } else {
                    let start = run
                        .submit_at
                        .max(shard.class_frontier[ci])
                        .max(shard.class_frontier[fg]);
                    let (e, contended) =
                        contended_end(&shard.class_frontier, qos, start, work);
                    end = e;
                    if contended {
                        let span = end - start;
                        for (i, &t) in run.tickets.iter().enumerate() {
                            self.completions[t] =
                                start + span * ((i + 1) as f64 / n as f64);
                        }
                    } else {
                        for (i, &t) in run.tickets.iter().enumerate() {
                            self.completions[t] = start + (i + 1) as f64 * svc;
                        }
                    }
                    d.commit_run(end, n as u64, run.size, run.op);
                    shard.class_frontier[ci] = shard.class_frontier[ci].max(end);
                    shard.class_frontier[fg] = shard.class_frontier[fg].max(end);
                }
                shard.class_busy[ci] += work;
                shard.frontier = shard.frontier.max(end);
                shard.epoch_frontier = shard.epoch_frontier.max(end);
                batch_done = batch_done.max(end);
            }
        }
        batch_done
    }

    /// Completion time of a drained ticket.
    pub fn completion(&self, ticket: Ticket) -> SimTime {
        self.completions[ticket]
    }

    /// Max epoch frontier over the current epoch's shards.
    pub fn wait_all(&self) -> SimTime {
        self.shards
            .values()
            .filter(|s| s.epoch == self.epoch)
            .fold(0.0, |t, s| t.max(s.epoch_frontier))
    }

    /// `(device, epoch frontier)` rows in BTreeMap (device) order.
    pub fn frontiers(&self) -> Vec<(usize, SimTime)> {
        self.shards
            .iter()
            .filter(|(_, s)| s.epoch == self.epoch)
            .map(|(&d, s)| (d, s.epoch_frontier))
            .collect()
    }

    /// `(tenant, class index, frontier, busy)` lane rows per device, in
    /// BTreeMap order — what the lane-order differential tests compare
    /// against the dense table's report.
    pub fn lane_rows(&self, device: usize) -> Vec<(TenantId, usize, SimTime, f64)> {
        self.shards.get(&device).map_or_else(Vec::new, |s| {
            s.lanes
                .iter()
                .map(|(&(t, ci), l)| (t, ci, l.frontier, l.busy))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceProfile;

    #[test]
    fn oracle_reproduces_basic_fifo_schedule() {
        let mut devs = vec![Device::new(DeviceProfile::ssd(1 << 40))];
        let mut o = OracleScheduler::new();
        let a = o.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        let b = o.submit(0, 0.0, 4096, IoOp::Write, Access::Seq);
        let done = o.drain(&mut devs);
        assert!(o.completion(a) < o.completion(b));
        assert_eq!(done, o.completion(b));
        assert_eq!(o.wait_all(), done);
        assert_eq!(o.frontiers(), vec![(0, done)]);
    }
}
