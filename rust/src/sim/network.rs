//! Interconnect model: point-to-point and collective costs.
//!
//! LogGP-flavoured: a message of `s` bytes costs `latency + s/bw`;
//! collectives add the usual `ceil(log2 p)` latency terms; aggregate
//! injection at a shared endpoint (e.g. all ranks writing to the PFS or
//! streaming to one consumer) is modelled by the endpoint's device queue
//! plus this model's per-link bandwidth.

use super::clock::SimTime;

/// Interconnect description.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Per-message latency, seconds (half RTT).
    pub latency: f64,
    /// Per-link bandwidth, bytes/s.
    pub link_bw: f64,
    /// Per-node injection bandwidth, bytes/s (caps fan-in/out).
    pub injection_bw: f64,
}

impl NetworkModel {
    /// FDR InfiniBand (SAGE prototype enclosure network, §3.1):
    /// ~56 Gb/s links, ~1 µs latency.
    pub fn fdr_infiniband() -> Self {
        NetworkModel { latency: 1e-6, link_bw: 6.8e9, injection_bw: 6.8e9 }
    }

    /// Cray Aries / Dragonfly (Beskow, §4.2).
    pub fn aries() -> Self {
        NetworkModel { latency: 1.3e-6, link_bw: 10e9, injection_bw: 10e9 }
    }

    /// Commodity 10GbE-ish (Tegner cluster fabric towards Lustre).
    pub fn tengig() -> Self {
        NetworkModel { latency: 20e-6, link_bw: 1.25e9, injection_bw: 1.25e9 }
    }

    /// Loopback (single workstation, Blackdog): effectively memcpy.
    pub fn loopback() -> Self {
        NetworkModel { latency: 0.2e-6, link_bw: 8e9, injection_bw: 8e9 }
    }

    /// Point-to-point message cost.
    pub fn pt2pt(&self, size: u64) -> SimTime {
        self.latency + size as f64 / self.link_bw
    }

    /// Barrier over `p` ranks (dissemination: log2(p) rounds).
    pub fn barrier(&self, p: usize) -> SimTime {
        self.latency * (p.max(1) as f64).log2().ceil().max(1.0)
    }

    /// Allreduce of `size` bytes over `p` ranks (Rabenseifner-style:
    /// 2·(p-1)/p · size transferred in log rounds).
    pub fn allreduce(&self, size: u64, p: usize) -> SimTime {
        if p <= 1 {
            return 0.0;
        }
        let rounds = (p as f64).log2().ceil();
        rounds * self.latency
            + 2.0 * size as f64 * (p as f64 - 1.0) / (p as f64) / self.link_bw
    }

    /// Gather of `size` bytes from each of `p` ranks to one root —
    /// fan-in is capped by the root's injection bandwidth.
    pub fn gather(&self, size: u64, p: usize) -> SimTime {
        let rounds = (p as f64).log2().ceil().max(1.0);
        rounds * self.latency
            + (size as f64 * (p as f64 - 1.0)) / self.injection_bw
    }

    /// Many-to-few fan-in: `producers` ranks each sending `size` bytes
    /// to one of `consumers` endpoints (the MPI-streams pattern). The
    /// consumer side is injection-limited; the producer side overlaps.
    pub fn fan_in(&self, size: u64, producers: usize, consumers: usize) -> SimTime {
        let per_consumer = producers.div_ceil(consumers.max(1));
        self.latency + size as f64 * per_consumer as f64 / self.injection_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pt2pt_latency_floor() {
        let n = NetworkModel::fdr_infiniband();
        assert!(n.pt2pt(0) >= 1e-6);
        assert!(n.pt2pt(1 << 30) > 0.1);
    }

    #[test]
    fn allreduce_grows_logarithmically() {
        let n = NetworkModel::aries();
        let t64 = n.allreduce(8, 64);
        let t4096 = n.allreduce(8, 4096);
        // small payload: latency-dominated, 2x rounds = 2x time
        assert!(t4096 / t64 < 2.5);
        assert!(t4096 > t64);
    }

    #[test]
    fn fan_in_scales_with_ratio() {
        let n = NetworkModel::aries();
        // 15:1 producer:consumer ratio (paper's streaming config)
        let t = n.fan_in(1 << 20, 150, 10);
        let t2 = n.fan_in(1 << 20, 300, 10);
        assert!(t2 > 1.9 * t && t2 < 2.1 * t);
    }

    #[test]
    fn single_rank_collectives_free() {
        let n = NetworkModel::loopback();
        assert_eq!(n.allreduce(1 << 20, 1), 0.0);
    }
}
