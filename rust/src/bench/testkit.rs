//! Shared geometry/payload builders for the differential test plane
//! (ISSUE 7 satellite): the sampled pool geometries and deterministic
//! payload generators that were duplicated across `prop_sched`,
//! `prop_qos`, `prop_repair` and `prop_storm` live here once.
//!
//! A [`Geometry`] names one extent-list sampling family: how many
//! extents a case draws, the block-index/length bounds, and the payload
//! multipliers that make every extent's bytes a pure function of its
//! coordinates. Each suite keeps its historical family (the constants
//! below) so the generated case sequences — and therefore the pinned
//! schedules — are unchanged by the extraction.
//!
//! Everything here is deterministic: same [`SimRng`] seed, same cases,
//! same payloads, same clients.

use crate::clovis::Client;
use crate::config::Testbed;
use crate::mero::{Layout, ObjectId};
use crate::sim::device::DeviceKind;
use crate::sim::rng::SimRng;

/// Block size every property suite creates objects with.
pub const BS: u64 = 4096;
/// Stripe unit every property suite lays objects out with.
pub const UNIT: u64 = 16384;

/// One extent-list sampling family: `n = 1 + gen_range(max_extra)`
/// extents of `(gen_range(max_index), 1 + gen_range(max_len))`
/// (block index, length in blocks), with payload byte `j` of extent
/// `(idx, lenb)` equal to `(idx*mul_idx + lenb*mul_len + j) % 251`.
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    /// `n = 1 + gen_range(max_extra)` extents per case.
    pub max_extra: u64,
    /// Extent start index is drawn from `[0, max_index)` blocks.
    pub max_index: u64,
    /// Extent length is `1 + gen_range(max_len)` blocks.
    pub max_len: u64,
    /// Payload multiplier on the extent index.
    pub mul_idx: u64,
    /// Payload multiplier on the extent length.
    pub mul_len: u64,
}

impl Geometry {
    /// The `prop_sched` family (ISSUE 2 suite).
    pub const SCHED: Geometry =
        Geometry { max_extra: 6, max_index: 64, max_len: 16, mul_idx: 137, mul_len: 29 };
    /// The `prop_qos` family (ISSUE 5 suite).
    pub const QOS: Geometry =
        Geometry { max_extra: 4, max_index: 32, max_len: 10, mul_idx: 173, mul_len: 57 };
    /// The `prop_repair` family (ISSUE 3 suite).
    pub const REPAIR: Geometry =
        Geometry { max_extra: 5, max_index: 48, max_len: 12, mul_idx: 151, mul_len: 43 };
    /// The `prop_tenant` family (ISSUE 7 suite).
    pub const TENANT: Geometry =
        Geometry { max_extra: 4, max_index: 40, max_len: 12, mul_idx: 163, mul_len: 31 };
    /// The `prop_qos_conserving` family (ISSUE 10 suite).
    pub const CONSERVE: Geometry =
        Geometry { max_extra: 4, max_index: 36, max_len: 10, mul_idx: 179, mul_len: 41 };

    /// Sample one extent list `(block index, length in blocks)`.
    pub fn gen_extents(&self, r: &mut SimRng) -> Vec<(u64, u64)> {
        let n = 1 + r.gen_range(self.max_extra) as usize;
        (0..n)
            .map(|_| (r.gen_range(self.max_index), 1 + r.gen_range(self.max_len)))
            .collect()
    }

    /// Deterministic payload for extent `(idx, len_blocks)`.
    pub fn bytes_for(&self, idx: u64, len_blocks: u64) -> Vec<u8> {
        (0..len_blocks * BS)
            .map(|j| {
                ((idx * self.mul_idx + len_blocks * self.mul_len + j) % 251) as u8
            })
            .collect()
    }
}

/// Total logical span of an extent list, in bytes.
pub fn span(extents: &[(u64, u64)]) -> u64 {
    extents.iter().map(|(i, l)| (i + l) * BS).max().unwrap_or(0)
}

/// The RAID layout every suite stripes with: `k+p` on the SSD tier at
/// [`UNIT`] granularity.
pub fn raid(k: u32, p: u32) -> Layout {
    Layout::Raid { data: k, parity: p, unit: UNIT, tier: DeviceKind::Ssd }
}

/// A fresh simulated client on the SAGE prototype rack — the cluster
/// every property suite runs against.
pub fn sage_client() -> Client {
    Client::new_sim(Testbed::sage_prototype())
}

/// Client with `n` small striped objects (default SSD 4+1 layout) and
/// RNG-filled payloads; returns the ids alongside their bytes.
pub fn populated(n: usize, seed: u64) -> (Client, Vec<(ObjectId, Vec<u8>)>) {
    let mut c = sage_client();
    let mut rng = SimRng::new(seed);
    let mut objs = Vec::new();
    for _ in 0..n {
        let id = c.create_object(BS).unwrap();
        let d = payload(&mut rng, 4 * 65536);
        c.write_object(&id, 0, &d).unwrap();
        objs.push((id, d));
    }
    (c, objs)
}

/// An RNG-filled payload of `len` bytes.
pub fn payload(rng: &mut SimRng, len: usize) -> Vec<u8> {
    let mut d = vec![0u8; len];
    rng.fill_bytes(&mut d);
    d
}

/// `(stripe, unit, device)` placement triples of an object, in
/// deterministic order — the cross-engine placement oracle.
pub fn placements(c: &Client, obj: ObjectId) -> Vec<(u64, u32, usize)> {
    c.store
        .object(obj)
        .unwrap()
        .placed_units()
        .map(|u| (u.stripe, u.unit, u.device))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometries_are_deterministic_and_in_bounds() {
        for geo in [
            Geometry::SCHED,
            Geometry::QOS,
            Geometry::REPAIR,
            Geometry::TENANT,
            Geometry::CONSERVE,
        ] {
            let a = geo.gen_extents(&mut SimRng::new(7));
            let b = geo.gen_extents(&mut SimRng::new(7));
            assert_eq!(a, b);
            assert!(!a.is_empty());
            assert!(a.len() as u64 <= 1 + geo.max_extra);
            for (i, l) in a {
                assert!(i < geo.max_index);
                assert!((1..=geo.max_len + 1).contains(&l));
            }
            assert_eq!(geo.bytes_for(3, 2), geo.bytes_for(3, 2));
            assert_eq!(geo.bytes_for(3, 2).len() as u64, 2 * BS);
        }
    }

    #[test]
    fn span_and_payload_builders() {
        assert_eq!(span(&[]), 0);
        assert_eq!(span(&[(2, 3), (1, 1)]), 5 * BS);
        let mut r = SimRng::new(11);
        let p = payload(&mut r, 64);
        assert_eq!(p.len(), 64);
        let mut r2 = SimRng::new(11);
        assert_eq!(p, payload(&mut r2, 64));
    }

    #[test]
    fn populated_clients_are_reproducible() {
        let (mut a, objs_a) = populated(2, 42);
        let (_b, objs_b) = populated(2, 42);
        assert_eq!(objs_a.len(), 2);
        for ((ia, da), (_ib, db)) in objs_a.iter().zip(objs_b.iter()) {
            assert_eq!(da, db);
            let got = a.read_object(ia, 0, da.len() as u64).unwrap();
            assert_eq!(&got, da);
            assert_eq!(placements(&a, *ia).len(), placements(&a, *ia).len());
        }
    }
}
