//! Benchmark harness (criterion-style, in-tree because the offline
//! build has no criterion). Drives the §4 evaluation benches and the
//! §Perf ablations — protocol in `rust/bench_results/README.md`,
//! module map in ARCHITECTURE.md.
//!
//! Two measurement modes:
//! * [`Bencher::wall`] — wall-clock timing with warmup and repeated
//!   iterations; reports median ± MAD. Used for the L3 hot-path perf
//!   work (§Perf in EXPERIMENTS.md).
//! * virtual-time experiments simply report the simulated makespan —
//!   the paper-figure benches use those directly.
//!
//! Results are appended to `bench_results/<name>.json` so the perf pass
//! can diff before/after.
//!
//! [`testkit`] holds the shared geometry/payload builders the
//! differential property suites (`rust/tests/prop_*.rs`) sample from.

pub mod testkit;

use std::time::Instant;

use crate::metrics::Stats;

/// One benchmark's configuration + results.
pub struct Bencher {
    pub name: String,
    warmup_iters: u32,
    measure_iters: u32,
}

/// Outcome of a wall-clock measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Median seconds per iteration.
    pub median: f64,
    /// Median absolute deviation.
    pub mad: f64,
    pub iters: u32,
}

impl Measurement {
    /// Human summary line (criterion-like).
    pub fn summary(&self) -> String {
        format!(
            "{:<40} time: [{} ± {}] ({} iters)",
            self.name,
            crate::metrics::fmt_secs(self.median),
            crate::metrics::fmt_secs(self.mad),
            self.iters
        )
    }

    /// Throughput line given bytes processed per iteration.
    pub fn throughput(&self, bytes: u64) -> String {
        format!(
            "{:<40} thrpt: {}",
            self.name,
            crate::util::bytes::fmt_bw(bytes as f64 / self.median.max(1e-12))
        )
    }
}

impl Bencher {
    /// Default: 3 warmup + 10 measured iterations.
    pub fn new(name: &str) -> Self {
        Bencher { name: name.to_string(), warmup_iters: 3, measure_iters: 10 }
    }

    /// Tune iteration counts (long-running sims use fewer).
    pub fn iters(mut self, warmup: u32, measure: u32) -> Self {
        self.warmup_iters = warmup;
        self.measure_iters = measure.max(1);
        self
    }

    /// Measure `f` by wall clock. The closure's return value is
    /// black-boxed to keep the optimizer honest.
    pub fn wall<T, F: FnMut() -> T>(&self, mut f: F) -> Measurement {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut s = Stats::new();
        let mut abs = Vec::new();
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            let dt = t0.elapsed().as_secs_f64();
            s.push(dt);
            abs.push(dt);
        }
        let median = s.median();
        let mut devs = Stats::new();
        for v in abs {
            devs.push((v - median).abs());
        }
        Measurement {
            name: self.name.clone(),
            median,
            mad: devs.median(),
            iters: self.measure_iters,
        }
    }
}

/// Append a result row to `bench_results/<bench>.json` (one JSON object
/// per line; the perf pass diffs these files).
pub fn record(bench: &str, fields: &[(&str, f64)]) {
    use std::io::Write as _;
    let dir = std::path::Path::new("bench_results");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let mut obj = String::from("{");
    for (i, (k, v)) in fields.iter().enumerate() {
        if i > 0 {
            obj.push(',');
        }
        obj.push_str(&format!("\"{k}\":{v}"));
    }
    obj.push('}');
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dir.join(format!("{bench}.json")))
    {
        let _ = writeln!(f, "{obj}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_measures_something() {
        let m = Bencher::new("spin").iters(1, 5).wall(|| {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.median > 0.0);
        assert_eq!(m.iters, 5);
        assert!(m.summary().contains("spin"));
    }

    #[test]
    fn throughput_formats() {
        let m = Measurement {
            name: "t".into(),
            median: 0.5,
            mad: 0.0,
            iters: 1,
        };
        assert!(m.throughput(1 << 30).contains("GB/s"));
    }
}
