//! MPI I/O baseline (the comparator in Fig 5).
//!
//! Models ROMIO-style *collective* I/O over the shared file system:
//! two-phase collective buffering (exchange to aggregators, then
//! aggregated writes to the PFS/disk), plus per-call collective-open
//! and synchronization latency that grows with the process count —
//! the scalability cost that makes MPI storage windows win at scale
//! (§4.1: "MPI storage windows provide better scalability compared to
//! MPI I/O on a larger number of processes").

use crate::config::Testbed;
use crate::sim::clock::{RankClocks, SimTime};
use crate::sim::device::{Access, Device, DeviceKind, IoOp};
use crate::sim::network::NetworkModel;

/// Shared-file write-contention coefficient: effective amplification is
/// `1 + ALPHA * nclients` (calibrated so 8192 clients see ~3.5x, which
/// reproduces Fig 7's 3.6x streaming advantage at that scale).
const SHARED_FILE_ALPHA: f64 = 3.5e-4;

/// Collective MPI-IO world over a testbed.
pub struct MpiIo {
    pub clocks: RankClocks,
    net: NetworkModel,
    /// PFS OSTs (or local disks on a workstation).
    targets: Vec<Device>,
    /// Aggregators per OST (ROMIO cb_nodes heuristic).
    aggregators: usize,
    /// Workstation (single OS page cache): read-after-write is served
    /// from DRAM when the file fits. On a PFS, collective reads
    /// revalidate against the OSTs (DLM locking), so no such benefit.
    local_cache: Option<(u64, f64)>, // (dram bytes, dram bw)
    /// Bytes written so far (cache-residency estimate).
    written: u64,
}

impl MpiIo {
    /// New world with `nranks` ranks.
    pub fn new(tb: &Testbed, nranks: usize) -> Self {
        let mut targets: Vec<Device> = tb
            .storage
            .iter()
            .filter(|p| p.kind == DeviceKind::LustreOst)
            .map(|p| Device::new(p.clone()))
            .collect();
        let mut local_cache = None;
        if targets.is_empty() {
            // workstation: the shared file lives on the HDD array (same
            // device class the storage-window comparison uses), behind
            // the node's page cache
            targets = tb
                .storage
                .iter()
                .filter(|p| p.kind == DeviceKind::Hdd)
                .map(|p| Device::new(p.clone()))
                .collect();
            if targets.is_empty() {
                targets = tb
                    .storage
                    .iter()
                    .filter(|p| p.kind == DeviceKind::Ssd)
                    .map(|p| Device::new(p.clone()))
                    .collect();
            }
            local_cache = Some((tb.dram_per_node, tb.dram_bw));
        }
        let aggregators = targets.len().max(1);
        MpiIo {
            clocks: RankClocks::new(nranks),
            net: tb.net.clone(),
            targets,
            aggregators,
            local_cache,
            written: 0,
        }
    }

    /// Collective write of `bytes_per_rank` from every rank
    /// (`MPI_File_write_all`). Returns completion time.
    pub fn write_all(&mut self, bytes_per_rank: u64) -> SimTime {
        self.written += bytes_per_rank * self.clocks.len() as u64;
        self.collective(bytes_per_rank, IoOp::Write)
    }

    /// Collective read (`MPI_File_read_all`). On a workstation,
    /// read-after-write is a page-cache hit when the file fits in DRAM.
    pub fn read_all(&mut self, bytes_per_rank: u64) -> SimTime {
        let p = self.clocks.len();
        let total = bytes_per_rank * p as u64;
        if let Some((dram, bw)) = self.local_cache {
            if self.written >= total && total <= dram / 2 {
                let t = self.clocks.max()
                    + total as f64 / bw
                    + self.net.barrier(p);
                for r in 0..p {
                    self.clocks.wait_until(r, t);
                }
                return t;
            }
        }
        self.collective(bytes_per_rank, IoOp::Read)
    }

    fn collective(&mut self, bytes_per_rank: u64, op: IoOp) -> SimTime {
        let p = self.clocks.len();
        let start = self.clocks.max();
        // Phase 0: collective open/sync — latency grows with log P but
        // the implicit allreduce of offsets costs per-rank messages.
        let t_sync = self.net.barrier(p) + self.net.allreduce(64, p);
        // Phase 1: data exchange to aggregators (all-to-few fan-in).
        let t_exchange =
            self.net.fan_in(bytes_per_rank, p, self.aggregators);
        // Phase 2: aggregated device I/O, striped across targets.
        // Shared-file collective I/O suffers lock contention / extent
        // ping-pong that grows with the client count (the well-known
        // Lustre shared-file scaling wall); reads are less affected.
        let contention = match op {
            IoOp::Write => 1.0 + SHARED_FILE_ALPHA * p as f64,
            IoOp::Read => 1.0 + 0.1 * SHARED_FILE_ALPHA * p as f64,
        };
        let total =
            (bytes_per_rank as f64 * p as f64 * contention) as u64;
        let per_target = total / self.targets.len().max(1) as u64;
        let mut t_io: f64 = 0.0;
        let t0 = start + t_sync + t_exchange;
        for dev in &mut self.targets {
            // sage-lint: allow(scheduler-discipline, "MPI-IO collective model: private Lustre targets, not the shared Mero plane")
            let t = dev.io(t0, per_target, op, Access::Seq);
            t_io = t_io.max(t);
        }
        // everyone leaves the collective together
        for r in 0..p {
            self.clocks.wait_until(r, t_io);
        }
        self.clocks.barrier(self.net.barrier(p))
    }

    /// Makespan.
    pub fn elapsed(&self) -> SimTime {
        self.clocks.max()
    }

    /// Reset clocks and device queues.
    pub fn reset(&mut self) {
        self.clocks.reset();
        for d in &mut self.targets {
            d.busy_until = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collective_write_scales_with_volume() {
        let tb = Testbed::tegner();
        let mut io = MpiIo::new(&tb, 24);
        let t1 = io.write_all(1 << 20);
        io.reset();
        let t2 = io.write_all(1 << 24);
        assert!(t2 > 4.0 * t1, "16x volume must cost clearly more: {t1} {t2}");
    }

    #[test]
    fn collective_overhead_grows_with_ranks() {
        let tb = Testbed::beskow();
        let bytes = 1u64 << 16; // small I/O: sync-dominated
        let mut small = MpiIo::new(&tb, 64);
        let t_small = small.write_all(bytes);
        let mut big = MpiIo::new(&tb, 8192);
        let t_big = big.write_all(bytes);
        assert!(
            t_big > t_small,
            "same per-rank bytes, more ranks => more collective cost"
        );
    }

    #[test]
    fn reads_faster_than_writes_on_lustre() {
        let tb = Testbed::tegner();
        let mut io = MpiIo::new(&tb, 24);
        let tw = io.write_all(1 << 24);
        io.reset();
        let tr = io.read_all(1 << 24);
        assert!(tw > 2.0 * tr, "Fig 3(b) asymmetry: write {tw} read {tr}");
    }

    #[test]
    fn workstation_fallback_uses_local_disks() {
        let tb = Testbed::blackdog();
        let mut io = MpiIo::new(&tb, 8);
        let t = io.write_all(1 << 20);
        assert!(t > 0.0);
    }
}
