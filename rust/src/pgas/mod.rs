//! PGAS I/O: MPI storage windows (§3.2.4, evaluated in §4.1).
//!
//! "Files on storage devices appear to users as MPI windows and are
//! seamlessly accessed through familiar PUT and GET operations. …
//! the OS page cache and buffering of the parallel file system act as
//! automatic caches for read and write operations on storage."
//!
//! [`PgasSim`] hosts N simulated ranks over a [`Testbed`]; windows are
//! allocated in DRAM ([`WindowKind::Memory`]) or as memory-mapped files
//! on a storage target ([`WindowKind::Storage`]). Storage-window
//! accesses go through a per-node [`PageCache`]: hits run at DRAM
//! speed, misses pay device reads, dirty pages are written back in the
//! background (they occupy the device queue without blocking the rank)
//! unless throttling kicks in, and `win_sync` forces a blocking flush.
//! Two OS constants — per-page fault and dirty-tracking costs — model
//! the mmap software overhead that separates storage windows from pure
//! DRAM windows on cached workloads (the ~10% of Fig 3a).
//!
//! Module map (ARCHITECTURE.md §Module map rows `pgas/`):
//!
//! * this module — the windows themselves: [`PgasSim`] rank hosting,
//!   PUT/GET/accumulate in virtual time, `win_sync` flush semantics,
//!   per-node page caches (`sim::cache`), and the Fig 3 measurement
//!   surface (`benches/fig3_stream.rs`, `examples/fig3_stream.rs`);
//! * [`mpiio`] — the MPI-I/O comparison layer the paper evaluates
//!   against (collective file writes over the same storage targets).
//!
//! PGAS windows model the §3.2.4 programming-model work and sit
//! BESIDE the Clovis object path: window storage targets are simulated
//! devices, not Mero objects, so rank-parallel window traffic and the
//! object store contend only when an application drives both (e.g.
//! `apps/ipic3d`). The broader stack — object I/O on the sharded
//! scheduler, the recovery plane, the QoS split between foreground
//! and rebuild traffic — is mapped in ARCHITECTURE.md (§Sharded
//! scheduler, §Recovery plane, §QoS plane) at the repo root.

pub mod mpiio;

use crate::config::Testbed;
use crate::error::{Result, SageError};
use crate::sim::cache::PageCache;
use crate::sim::clock::{RankClocks, SimTime};
use crate::sim::device::{Access, Device, DeviceKind, IoOp};
use crate::sim::network::NetworkModel;

/// Where a window lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowKind {
    /// Classic MPI window in DRAM.
    Memory,
    /// MPI *storage* window: memory-mapped file on a device class.
    Storage(StorageTarget),
}

/// Which storage backs a storage window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageTarget {
    /// Node-local HDD (Blackdog default).
    Hdd,
    /// Node-local SSD.
    Ssd,
    /// The shared parallel file system (Tegner/Beskow Lustre).
    Pfs,
}

/// Handle to an allocated window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowId(usize);

/// Page-fault cost on first touch (mmap minor fault + zero-fill), s/page.
const FAULT_COST: f64 = 0.06e-6;
/// Dirty-tracking cost per dirtied page (page-table walk + radix-tree
/// tagging on the write path of a file-backed mapping), s/page.
const DIRTY_COST: f64 = 0.08e-6;
/// Page size used for the OS-overhead accounting.
const PAGE: f64 = 4096.0;

struct Window {
    kind: WindowKind,
    size_per_rank: u64,
    /// Per-rank page cache state (storage windows only). Indexed by
    /// rank; models that rank's slice of the node page cache.
    caches: Vec<Option<PageCache>>,
}

/// The PGAS world: ranks, clocks, devices, caches.
pub struct PgasSim {
    pub tb: Testbed,
    pub clocks: RankClocks,
    pub net: NetworkModel,
    node_of_rank: Vec<usize>,
    /// Storage devices by target class.
    hdd: Vec<Device>,
    ssd: Vec<Device>,
    pfs: Vec<Device>,
    windows: Vec<Window>,
    dram_bw: f64,
}

impl PgasSim {
    /// A world of `nranks` ranks over `tb`, round-robin across nodes.
    pub fn new(tb: Testbed, nranks: usize) -> Self {
        let nodes = tb.compute_nodes.max(1);
        let per_node = tb.cores_per_node.max(1);
        let node_of_rank =
            (0..nranks).map(|r| (r / per_node) % nodes).collect();
        let mut hdd = Vec::new();
        let mut ssd = Vec::new();
        let mut pfs = Vec::new();
        for p in &tb.storage {
            match p.kind {
                DeviceKind::Hdd | DeviceKind::Smr => hdd.push(Device::new(p.clone())),
                DeviceKind::Ssd | DeviceKind::Nvram => ssd.push(Device::new(p.clone())),
                DeviceKind::LustreOst => pfs.push(Device::new(p.clone())),
                DeviceKind::Dram => {}
            }
        }
        PgasSim {
            net: tb.net.clone(),
            clocks: RankClocks::new(nranks),
            node_of_rank,
            hdd,
            ssd,
            pfs,
            windows: Vec::new(),
            dram_bw: tb.dram_bw,
            tb,
        }
    }

    /// Allocate a window of `size_per_rank` bytes on every rank
    /// (`MPI_Win_allocate` analog; storage windows pass the target as
    /// the MPI info key the paper proposes).
    pub fn alloc_window(&mut self, kind: WindowKind, size_per_rank: u64) -> WindowId {
        let n = self.clocks.len();
        let caches = match kind {
            WindowKind::Memory => (0..n).map(|_| None).collect(),
            WindowKind::Storage(target) => {
                let per_node_ranks = self
                    .node_of_rank
                    .iter()
                    .filter(|&&nd| nd == self.node_of_rank[0])
                    .count()
                    .max(1);
                // each rank gets its slice of the node's page cache
                let slice = self.tb.dram_per_node / per_node_ranks as u64;
                let dirty_ratio = match target {
                    // Lustre's llite caps dirty pages per OSC aggressively
                    StorageTarget::Pfs => 0.04,
                    _ => 0.40,
                };
                // cache-page granularity: 4 KiB for small windows up to
                // 2 MiB (THP-like) for huge ones — bounds map size
                let page = (size_per_rank / 4096)
                    .next_power_of_two()
                    .clamp(4096, 2 << 20);
                // PFS clients throttle at a fixed dirty budget
                // (llite max_dirty_mb analog), not a DRAM fraction
                let cap = match target {
                    // llite per-client dirty budget (osc.max_dirty_mb)
                    StorageTarget::Pfs => 32 << 20,
                    _ => u64::MAX,
                };
                (0..n)
                    .map(|_| {
                        Some(
                            PageCache::new(slice, page)
                                .with_dirty_ratio(dirty_ratio)
                                .with_dirty_cap(cap),
                        )
                    })
                    .collect()
            }
        };
        self.windows.push(Window { kind, size_per_rank, caches });
        WindowId(self.windows.len() - 1)
    }

    /// Charge a device transfer. Local targets hit the rank-affine
    /// device; the PFS stripes the transfer in 1 MiB units across OSTs
    /// (Lustre striping), so large transfers see aggregate bandwidth.
    fn device_io(
        &mut self,
        target: StorageTarget,
        rank: usize,
        offset: u64,
        bytes: u64,
        op: IoOp,
        access: Access,
        t: SimTime,
    ) -> SimTime {
        const STRIPE: u64 = 1 << 20;
        let pool: &mut Vec<Device> = match target {
            StorageTarget::Hdd => &mut self.hdd,
            StorageTarget::Ssd => &mut self.ssd,
            StorageTarget::Pfs => &mut self.pfs,
        };
        if pool.is_empty() {
            return t;
        }
        match target {
            StorageTarget::Pfs => {
                let n = pool.len();
                let mut done = t;
                let mut off = offset;
                let mut left = bytes;
                while left > 0 {
                    let len = STRIPE.min(left);
                    let idx = ((off / STRIPE) as usize + rank) % n;
                    // sage-lint: allow(scheduler-discipline, "PGAS window model: private per-window device pools, not the shared Mero plane")
                    let end = pool[idx].io(t, len, op, access);
                    done = done.max(end);
                    off += len;
                    left -= len;
                }
                done
            }
            _ => {
                let idx = rank % pool.len();
                // sage-lint: allow(scheduler-discipline, "PGAS window model: private per-window device pools, not the shared Mero plane")
                pool[idx].io(t, bytes, op, access)
            }
        }
    }

    /// One-sided PUT: `rank` writes `len` bytes at `offset` in
    /// `target_rank`'s window segment. Returns the rank's new time.
    pub fn put(
        &mut self,
        win: WindowId,
        rank: usize,
        target_rank: usize,
        offset: u64,
        len: u64,
        random: bool,
    ) -> Result<SimTime> {
        self.access(win, rank, target_rank, offset, len, IoOp::Write, random)
    }

    /// One-sided GET.
    pub fn get(
        &mut self,
        win: WindowId,
        rank: usize,
        target_rank: usize,
        offset: u64,
        len: u64,
        random: bool,
    ) -> Result<SimTime> {
        self.access(win, rank, target_rank, offset, len, IoOp::Read, random)
    }

    #[allow(clippy::too_many_arguments)]
    fn access(
        &mut self,
        win: WindowId,
        rank: usize,
        target_rank: usize,
        offset: u64,
        len: u64,
        op: IoOp,
        random: bool,
    ) -> Result<SimTime> {
        let w = self
            .windows
            .get(win.0)
            .ok_or_else(|| SageError::NotFound(format!("window {win:?}")))?;
        if offset + len > w.size_per_rank {
            return Err(SageError::Invalid(format!(
                "window access past end: {offset}+{len} > {}",
                w.size_per_rank
            )));
        }
        let kind = w.kind;
        let now = self.clocks.now(rank);
        let mut t = now;

        // network hop for remote targets
        let remote = self.node_of_rank[rank] != self.node_of_rank[target_rank];
        if remote {
            t += self.net.pt2pt(len);
        } else if rank != target_rank {
            t += self.net.latency; // same node, cross-process
        }

        match kind {
            WindowKind::Memory => {
                t += len as f64 / self.dram_bw;
            }
            WindowKind::Storage(target) => {
                // page-cache interaction happens on the *target* rank's
                // node; cache state is per-rank slice
                let access =
                    if random { Access::Random } else { Access::Seq };
                let outcome = {
                    let w = &mut self.windows[win.0];
                    let cache = w.caches[target_rank]
                        .as_mut()
                        .expect("storage window has caches");
                    match op {
                        IoOp::Read => cache.read(offset, len),
                        IoOp::Write => cache.write(offset, len),
                    }
                };
                // DRAM time for the bytes that hit / were absorbed
                t += outcome.hit as f64 / self.dram_bw;
                // OS overheads: faults on misses, dirty tracking on writes
                t += (outcome.miss as f64 / PAGE).ceil() * FAULT_COST;
                if op == IoOp::Write {
                    t += (len as f64 / PAGE).ceil() * DIRTY_COST;
                }
                // misses: blocking device reads
                if outcome.miss > 0 {
                    t = self.device_io(
                        target, target_rank, offset, outcome.miss,
                        IoOp::Read, access, t,
                    );
                }
                // throttled/evicted writeback: blocking
                if outcome.writeback > 0 {
                    t = self.device_io(
                        target, target_rank, offset, outcome.writeback,
                        IoOp::Write, access, t,
                    );
                }
            }
        }
        Ok(self.clocks.wait_until(rank, t))
    }

    /// `MPI_Win_sync` analog: blocking flush of the rank's dirty pages.
    pub fn win_sync(&mut self, win: WindowId, rank: usize) -> Result<SimTime> {
        let kind = self.windows[win.0].kind;
        let now = self.clocks.now(rank);
        let mut t = now;
        if let WindowKind::Storage(target) = kind {
            let dirty = {
                let w = &mut self.windows[win.0];
                w.caches[rank].as_mut().map(|c| c.sync()).unwrap_or(0)
            };
            if dirty > 0 {
                t = self.device_io(
                    target, rank, 0, dirty, IoOp::Write, Access::Seq, t,
                );
            }
        }
        Ok(self.clocks.wait_until(rank, t))
    }

    /// `MPI_Win_fence` analog: sync every rank then barrier.
    pub fn fence(&mut self, win: WindowId) -> Result<SimTime> {
        for r in 0..self.clocks.len() {
            self.win_sync(win, r)?;
        }
        Ok(self.clocks.barrier(self.net.barrier(self.clocks.len())))
    }

    /// Pre-touch a window segment (STREAM-style init before the timed
    /// region): populates the cache without charging the rank clock.
    pub fn warm(&mut self, win: WindowId, rank: usize) {
        let (kind, size) = {
            let w = &self.windows[win.0];
            (w.kind, w.size_per_rank)
        };
        if let WindowKind::Storage(_) = kind {
            let w = &mut self.windows[win.0];
            if let Some(c) = w.caches[rank].as_mut() {
                c.read(0, size);
            }
        }
    }

    /// Charge pure local compute to a rank.
    pub fn compute(&mut self, rank: usize, seconds: f64) -> SimTime {
        self.clocks.advance(rank, seconds)
    }

    /// Makespan across ranks.
    pub fn elapsed(&self) -> SimTime {
        self.clocks.max()
    }

    /// Number of ranks.
    pub fn nranks(&self) -> usize {
        self.clocks.len()
    }

    /// Total bytes written to each device class: (hdd, ssd, pfs) —
    /// diagnostics for benches and tests.
    pub fn bytes_written(&self) -> (u64, u64, u64) {
        let sum = |v: &Vec<Device>| v.iter().map(|d| d.bytes_written).sum();
        (sum(&self.hdd), sum(&self.ssd), sum(&self.pfs))
    }

    /// Reset clocks (new measurement) but keep cache/device state.
    pub fn reset_clocks(&mut self) {
        self.clocks.reset();
        for d in self
            .hdd
            .iter_mut()
            .chain(self.ssd.iter_mut())
            .chain(self.pfs.iter_mut())
        {
            d.busy_until = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(n: usize) -> PgasSim {
        PgasSim::new(Testbed::blackdog(), n)
    }

    #[test]
    fn memory_window_is_dram_speed() {
        let mut s = sim(1);
        let w = s.alloc_window(WindowKind::Memory, 1 << 30);
        s.put(w, 0, 0, 0, 1 << 30, false).unwrap();
        let t = s.elapsed();
        let expect = (1u64 << 30) as f64 / s.tb.dram_bw;
        assert!((t - expect).abs() / expect < 0.05, "t={t} expect={expect}");
    }

    #[test]
    fn storage_window_close_to_memory_when_cached() {
        let mut s = sim(1);
        let size = 1u64 << 28; // 256 MiB << 72 GiB DRAM
        let wm = s.alloc_window(WindowKind::Memory, size);
        let ws = s.alloc_window(WindowKind::Storage(StorageTarget::Hdd), size);
        s.warm(ws, 0);
        s.put(wm, 0, 0, 0, size, false).unwrap();
        let t_mem = s.elapsed();
        s.reset_clocks();
        s.put(ws, 0, 0, 0, size, false).unwrap();
        let t_sto = s.elapsed();
        let overhead = t_sto / t_mem - 1.0;
        assert!(
            overhead > 0.02 && overhead < 0.6,
            "cached storage window should be within tens of % of DRAM \
             (got {overhead:+.2})"
        );
    }

    #[test]
    fn win_sync_pays_device_writes() {
        let mut s = sim(1);
        let size = 1u64 << 24; // 16 MiB dirty
        let ws = s.alloc_window(WindowKind::Storage(StorageTarget::Hdd), size);
        s.put(ws, 0, 0, 0, size, false).unwrap();
        let before = s.elapsed();
        s.win_sync(ws, 0).unwrap();
        let after = s.elapsed();
        // 16 MiB at ~140 MB/s HDD write: >= 0.1 s
        assert!(after - before > 0.05, "sync cost {}", after - before);
    }

    #[test]
    fn pfs_windows_throttle_writes() {
        let mut t = PgasSim::new(Testbed::tegner(), 1);
        let size = 1u64 << 30;
        let ws = t.alloc_window(WindowKind::Storage(StorageTarget::Pfs), size);
        t.warm(ws, 0);
        t.put(ws, 0, 0, 0, size, false).unwrap();
        let t_sto = t.elapsed();
        t.reset_clocks();
        let wm = t.alloc_window(WindowKind::Memory, size);
        t.put(wm, 0, 0, 0, size, false).unwrap();
        let t_mem = t.elapsed();
        assert!(
            t_sto > 5.0 * t_mem,
            "Lustre writes should collapse vs DRAM: {t_sto} vs {t_mem}"
        );
    }

    #[test]
    fn remote_put_pays_network() {
        let mut s = PgasSim::new(Testbed::tegner(), 48);
        let w = s.alloc_window(WindowKind::Memory, 1 << 20);
        // rank 0 (node 0) -> rank 47 (node 1)
        s.put(w, 0, 47, 0, 1 << 20, false).unwrap();
        let t_remote = s.clocks.now(0);
        s.reset_clocks();
        s.put(w, 0, 0, 0, 1 << 20, false).unwrap();
        let t_local = s.clocks.now(0);
        assert!(t_remote > t_local);
    }

    #[test]
    fn bounds_checked() {
        let mut s = sim(1);
        let w = s.alloc_window(WindowKind::Memory, 1024);
        assert!(s.put(w, 0, 0, 1000, 100, false).is_err());
    }

    #[test]
    fn fence_synchronizes_clocks() {
        let mut s = sim(4);
        let w = s.alloc_window(WindowKind::Memory, 1 << 20);
        s.put(w, 2, 2, 0, 1 << 20, false).unwrap();
        s.fence(w).unwrap();
        let t = s.clocks.now(0);
        for r in 0..4 {
            assert_eq!(s.clocks.now(r), t);
        }
    }
}

impl PgasSim {
    /// Per-PFS-device (bytes_written, busy_until) — debug diagnostics.
    #[doc(hidden)]
    pub fn pfs_debug(&self) -> Vec<(u64, f64)> {
        self.pfs.iter().map(|d| (d.bytes_written, d.busy_until)).collect()
    }
}

impl PgasSim {
    /// Dirty bytes in a rank's window cache — debug diagnostics.
    #[doc(hidden)]
    pub fn window_dirty(&self, win: WindowId, rank: usize) -> u64 {
        self.windows[win.0].caches[rank]
            .as_ref()
            .map(|c| c.dirty())
            .unwrap_or(0)
    }
}
