//! Simulated cluster: storage nodes with in-enclosure compute, device
//! inventory, and the interconnect (§3.1: enclosures with embedded x86
//! compute joined by FDR InfiniBand; compute capability increases for
//! faster tiers).

pub mod failure;

use crate::sim::clock::SimTime;
use crate::sim::device::{Access, Device, DeviceProfile, IoOp};
use crate::sim::network::NetworkModel;
use crate::sim::sched::QosConfig;

/// Index of a storage node.
pub type NodeId = usize;
/// Index of a device in the cluster inventory.
pub type DeviceId = usize;

/// In-enclosure compute capability (standard x86 embedded parts; used
/// to cost function-shipped computations on storage nodes).
#[derive(Debug, Clone)]
pub struct EnclosureCompute {
    pub cores: u32,
    /// Aggregate throughput for shipped kernels, FLOP/s-equivalent.
    pub flops: f64,
}

/// One storage enclosure/node.
#[derive(Debug, Clone)]
pub struct StorageNode {
    pub id: NodeId,
    pub devices: Vec<DeviceId>,
    pub compute: EnclosureCompute,
}

/// The simulated SAGE cluster.
#[derive(Debug)]
pub struct Cluster {
    pub nodes: Vec<StorageNode>,
    pub devices: Vec<Device>,
    pub net: NetworkModel,
    /// The repair/foreground bandwidth split every Clovis op group
    /// built on this cluster enforces (§3.2.1 repair throttling; see
    /// `sim::sched` and OPERATIONS.md §QoS tuning). Defaults to the
    /// sane split (repair 0.30, migration 0.20); set to
    /// [`QosConfig::unlimited`] to restore the pre-QoS FIFO schedule.
    pub qos: QosConfig,
}

impl Cluster {
    /// Empty cluster over a given network, with the default QoS split.
    pub fn new(net: NetworkModel) -> Self {
        Cluster {
            nodes: Vec::new(),
            devices: Vec::new(),
            net,
            qos: QosConfig::default(),
        }
    }

    /// Add a node with the given device profiles and compute capability;
    /// returns its id. Per §3.1, faster tiers get more compute.
    pub fn add_node(
        &mut self,
        profiles: Vec<DeviceProfile>,
        compute: EnclosureCompute,
    ) -> NodeId {
        let id = self.nodes.len();
        let mut dev_ids = Vec::with_capacity(profiles.len());
        for p in profiles {
            dev_ids.push(self.add_device(p));
        }
        self.nodes.push(StorageNode { id, devices: dev_ids, compute });
        id
    }

    /// Add a standalone device; returns its id.
    pub fn add_device(&mut self, profile: DeviceProfile) -> DeviceId {
        let id = self.devices.len();
        self.devices.push(Device::new(profile));
        id
    }

    /// Node owning `dev`, if any.
    pub fn node_of(&self, dev: DeviceId) -> Option<NodeId> {
        self.nodes
            .iter()
            .find(|n| n.devices.contains(&dev))
            .map(|n| n.id)
    }

    /// Submit an I/O to `dev` at `now`; returns completion time.
    pub fn io(
        &mut self,
        dev: DeviceId,
        now: SimTime,
        size: u64,
        op: IoOp,
        access: Access,
    ) -> SimTime {
        self.devices[dev].io(now, size, op, access)
    }

    /// All non-failed devices of a kind predicate.
    pub fn devices_where<F: Fn(&Device) -> bool>(&self, f: F) -> Vec<DeviceId> {
        self.devices
            .iter()
            .enumerate()
            .filter(|(_, d)| !d.failed && f(d))
            .map(|(i, _)| i)
            .collect()
    }

    /// Mark a device failed (drives HA repair, §3.2.1).
    pub fn fail_device(&mut self, dev: DeviceId) {
        self.devices[dev].failed = true;
    }

    /// Restore a repaired/replaced device (empty).
    pub fn replace_device(&mut self, dev: DeviceId) {
        let d = &mut self.devices[dev];
        d.failed = false;
        d.used = 0;
    }

    /// Cost of running a shipped computation of `flops` on `node`.
    pub fn compute_time(&self, node: NodeId, flops: f64) -> SimTime {
        flops / self.nodes[node].compute.flops.max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceKind;

    fn mini() -> Cluster {
        let mut c = Cluster::new(NetworkModel::fdr_infiniband());
        c.add_node(
            vec![DeviceProfile::nvram(1 << 30), DeviceProfile::ssd(1 << 34)],
            EnclosureCompute { cores: 16, flops: 5e10 },
        );
        c.add_node(
            vec![DeviceProfile::hdd(1 << 40)],
            EnclosureCompute { cores: 4, flops: 1e10 },
        );
        c
    }

    #[test]
    fn topology() {
        let c = mini();
        assert_eq!(c.nodes.len(), 2);
        assert_eq!(c.devices.len(), 3);
        assert_eq!(c.node_of(0), Some(0));
        assert_eq!(c.node_of(2), Some(1));
    }

    #[test]
    fn failure_excludes_device() {
        let mut c = mini();
        let ssds = c.devices_where(|d| d.profile.kind == DeviceKind::Ssd);
        assert_eq!(ssds.len(), 1);
        c.fail_device(ssds[0]);
        assert!(c
            .devices_where(|d| d.profile.kind == DeviceKind::Ssd)
            .is_empty());
        c.replace_device(ssds[0]);
        assert_eq!(
            c.devices_where(|d| d.profile.kind == DeviceKind::Ssd).len(),
            1
        );
    }

    #[test]
    fn faster_node_computes_faster() {
        let c = mini();
        assert!(c.compute_time(0, 1e9) < c.compute_time(1, 1e9));
    }
}
