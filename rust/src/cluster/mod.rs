//! Simulated cluster: storage nodes with in-enclosure compute, device
//! inventory, and the interconnect (§3.1: enclosures with embedded x86
//! compute joined by FDR InfiniBand; compute capability increases for
//! faster tiers).
//!
//! ## Failure topology
//!
//! §3.2.1 expects "several hardware failures per second at Exascale",
//! and production failures are spatially CORRELATED: a PDU trip or
//! cooling loss takes out every device under one domain at once. The
//! cluster therefore carries a three-level failure topology — device →
//! enclosure (one [`StorageNode`]) → rack (a group of enclosures,
//! [`StorageNode::rack`]) — and [`Cluster::domain_devices`] enumerates
//! the blast radius of a [`FailureDomain`]. The correlated generators
//! in [`failure`] ([`failure::FailureSchedule::storm`] and the mixed
//! storm+background sampler) draw their targets from these domains.

pub mod failure;

use crate::sim::clock::SimTime;
use crate::sim::device::{Access, Device, DeviceProfile, IoOp};
use crate::sim::network::NetworkModel;
use crate::sim::sched::{QosConfig, TenantShares};

/// Index of a storage node.
pub type NodeId = usize;
/// Index of a device in the cluster inventory.
pub type DeviceId = usize;
/// Index of a rack (the failure domain above the enclosure).
pub type RackId = usize;

/// Enclosures per rack under the default assignment of
/// [`Cluster::add_node`] (rack = node id / this).
pub const ENCLOSURES_PER_RACK: usize = 2;

/// One level of the cluster's failure topology: the set of devices a
/// correlated failure strikes together.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureDomain {
    /// A single device (the uncorrelated case).
    Device(DeviceId),
    /// Every device of one enclosure/node (backplane, PSU).
    Enclosure(NodeId),
    /// Every device of every enclosure in one rack (PDU, cooling).
    Rack(RackId),
}

/// In-enclosure compute capability (standard x86 embedded parts; used
/// to cost function-shipped computations on storage nodes).
#[derive(Debug, Clone)]
pub struct EnclosureCompute {
    pub cores: u32,
    /// Aggregate throughput for shipped kernels, FLOP/s-equivalent.
    pub flops: f64,
}

/// One storage enclosure/node.
#[derive(Debug, Clone)]
pub struct StorageNode {
    pub id: NodeId,
    pub devices: Vec<DeviceId>,
    pub compute: EnclosureCompute,
    /// Rack this enclosure sits in ([`Cluster::add_node`] assigns
    /// `id / ENCLOSURES_PER_RACK`; [`Cluster::add_node_in_rack`] takes
    /// it explicitly).
    pub rack: RackId,
}

/// The simulated SAGE cluster.
#[derive(Debug)]
pub struct Cluster {
    pub nodes: Vec<StorageNode>,
    pub devices: Vec<Device>,
    pub net: NetworkModel,
    /// The repair/foreground bandwidth split every Clovis op group
    /// built on this cluster enforces (§3.2.1 repair throttling; see
    /// `sim::sched` and OPERATIONS.md §QoS tuning). Defaults to the
    /// sane split (repair 0.30, migration 0.20); set to
    /// [`QosConfig::unlimited`] to restore the pre-QoS FIFO schedule.
    pub qos: QosConfig,
    /// The weighted per-tenant fair-share table (ISSUE 7 multi-tenant
    /// plane; see `sim::sched::TenantShares` and OPERATIONS.md
    /// §Tenant shares). Starts single-tenant (plane inactive —
    /// schedules bit-identical to per-class QoS);
    /// `Client::register_tenant` admits more.
    pub tenants: TenantShares,
}

impl Cluster {
    /// Empty cluster over a given network, with the default QoS split
    /// and a single-tenant table.
    pub fn new(net: NetworkModel) -> Self {
        Cluster {
            nodes: Vec::new(),
            devices: Vec::new(),
            net,
            qos: QosConfig::default(),
            tenants: TenantShares::single(),
        }
    }

    /// Add a node with the given device profiles and compute capability;
    /// returns its id. Per §3.1, faster tiers get more compute. Racks
    /// group consecutive enclosures [`ENCLOSURES_PER_RACK`] at a time.
    pub fn add_node(
        &mut self,
        profiles: Vec<DeviceProfile>,
        compute: EnclosureCompute,
    ) -> NodeId {
        let rack = self.nodes.len() / ENCLOSURES_PER_RACK;
        self.add_node_in_rack(profiles, compute, rack)
    }

    /// [`Cluster::add_node`] with an explicit rack assignment (testbeds
    /// modelling a concrete machine-room layout).
    pub fn add_node_in_rack(
        &mut self,
        profiles: Vec<DeviceProfile>,
        compute: EnclosureCompute,
        rack: RackId,
    ) -> NodeId {
        let id = self.nodes.len();
        let mut dev_ids = Vec::with_capacity(profiles.len());
        for p in profiles {
            dev_ids.push(self.add_device(p));
        }
        self.nodes.push(StorageNode { id, devices: dev_ids, compute, rack });
        id
    }

    /// Add a standalone device; returns its id.
    pub fn add_device(&mut self, profile: DeviceProfile) -> DeviceId {
        let id = self.devices.len();
        self.devices.push(Device::new(profile));
        id
    }

    /// Attach a device to an EXISTING enclosure at runtime (elastic
    /// capacity under load); returns its id. The pool layer must also
    /// learn about it — `MeroStore::attach_device` does both.
    pub fn attach_device(
        &mut self,
        node: NodeId,
        profile: DeviceProfile,
    ) -> DeviceId {
        let id = self.add_device(profile);
        self.nodes[node].devices.push(id);
        id
    }

    /// Node owning `dev`, if any.
    pub fn node_of(&self, dev: DeviceId) -> Option<NodeId> {
        self.nodes
            .iter()
            .find(|n| n.devices.contains(&dev))
            .map(|n| n.id)
    }

    /// Rack holding `dev`, if it belongs to any enclosure.
    pub fn rack_of(&self, dev: DeviceId) -> Option<RackId> {
        self.node_of(dev).map(|n| self.nodes[n].rack)
    }

    /// Number of racks (highest rack id + 1; 0 for an empty cluster).
    pub fn racks(&self) -> usize {
        self.nodes.iter().map(|n| n.rack + 1).max().unwrap_or(0)
    }

    /// Every device under `domain` — the blast radius of a correlated
    /// failure there. Includes already-failed devices; callers filter.
    pub fn domain_devices(&self, domain: FailureDomain) -> Vec<DeviceId> {
        match domain {
            FailureDomain::Device(d) => {
                if d < self.devices.len() {
                    vec![d]
                } else {
                    Vec::new()
                }
            }
            FailureDomain::Enclosure(n) => self
                .nodes
                .get(n)
                .map(|node| node.devices.clone())
                .unwrap_or_default(),
            FailureDomain::Rack(r) => self
                .nodes
                .iter()
                .filter(|n| n.rack == r)
                .flat_map(|n| n.devices.iter().copied())
                .collect(),
        }
    }

    /// Submit an I/O to `dev` at `now`; returns completion time.
    pub fn io(
        &mut self,
        dev: DeviceId,
        now: SimTime,
        size: u64,
        op: IoOp,
        access: Access,
    ) -> SimTime {
        // sage-lint: allow(scheduler-discipline, "the retained single-I/O primitive: sanctioned probes (fshipping) bottom out here")
        self.devices[dev].io(now, size, op, access)
    }

    /// All non-failed devices of a kind predicate.
    pub fn devices_where<F: Fn(&Device) -> bool>(&self, f: F) -> Vec<DeviceId> {
        self.devices
            .iter()
            .enumerate()
            .filter(|(_, d)| !d.failed && f(d))
            .map(|(i, _)| i)
            .collect()
    }

    /// Mark a device failed (drives HA repair, §3.2.1).
    pub fn fail_device(&mut self, dev: DeviceId) {
        self.devices[dev].failed = true;
    }

    /// Restore a repaired/replaced device (empty).
    pub fn replace_device(&mut self, dev: DeviceId) {
        let d = &mut self.devices[dev];
        d.failed = false;
        d.used = 0;
    }

    /// Cost of running a shipped computation of `flops` on `node`.
    pub fn compute_time(&self, node: NodeId, flops: f64) -> SimTime {
        flops / self.nodes[node].compute.flops.max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceKind;

    fn mini() -> Cluster {
        let mut c = Cluster::new(NetworkModel::fdr_infiniband());
        c.add_node(
            vec![DeviceProfile::nvram(1 << 30), DeviceProfile::ssd(1 << 34)],
            EnclosureCompute { cores: 16, flops: 5e10 },
        );
        c.add_node(
            vec![DeviceProfile::hdd(1 << 40)],
            EnclosureCompute { cores: 4, flops: 1e10 },
        );
        c
    }

    #[test]
    fn topology() {
        let c = mini();
        assert_eq!(c.nodes.len(), 2);
        assert_eq!(c.devices.len(), 3);
        assert_eq!(c.node_of(0), Some(0));
        assert_eq!(c.node_of(2), Some(1));
    }

    #[test]
    fn failure_excludes_device() {
        let mut c = mini();
        let ssds = c.devices_where(|d| d.profile.kind == DeviceKind::Ssd);
        assert_eq!(ssds.len(), 1);
        c.fail_device(ssds[0]);
        assert!(c
            .devices_where(|d| d.profile.kind == DeviceKind::Ssd)
            .is_empty());
        c.replace_device(ssds[0]);
        assert_eq!(
            c.devices_where(|d| d.profile.kind == DeviceKind::Ssd).len(),
            1
        );
    }

    #[test]
    fn faster_node_computes_faster() {
        let c = mini();
        assert!(c.compute_time(0, 1e9) < c.compute_time(1, 1e9));
    }

    #[test]
    fn failure_domains_nest_device_enclosure_rack() {
        let mut c = mini();
        // a third node lands in rack 1 under the default grouping
        c.add_node(
            vec![DeviceProfile::smr(1 << 40)],
            EnclosureCompute { cores: 4, flops: 1e10 },
        );
        assert_eq!(c.nodes[0].rack, 0);
        assert_eq!(c.nodes[1].rack, 0);
        assert_eq!(c.nodes[2].rack, 1);
        assert_eq!(c.racks(), 2);
        assert_eq!(c.rack_of(0), Some(0));
        assert_eq!(c.rack_of(3), Some(1));
        assert_eq!(c.domain_devices(FailureDomain::Device(1)), vec![1]);
        assert_eq!(c.domain_devices(FailureDomain::Enclosure(0)), vec![0, 1]);
        assert_eq!(c.domain_devices(FailureDomain::Rack(0)), vec![0, 1, 2]);
        assert_eq!(c.domain_devices(FailureDomain::Rack(1)), vec![3]);
        // out-of-range domains are empty, not panics
        assert!(c.domain_devices(FailureDomain::Device(99)).is_empty());
        assert!(c.domain_devices(FailureDomain::Enclosure(99)).is_empty());
        assert!(c.domain_devices(FailureDomain::Rack(99)).is_empty());
    }

    #[test]
    fn explicit_rack_assignment_and_attach() {
        let mut c = Cluster::new(NetworkModel::fdr_infiniband());
        let n0 = c.add_node_in_rack(
            vec![DeviceProfile::ssd(1 << 34)],
            EnclosureCompute { cores: 16, flops: 5e10 },
            7,
        );
        assert_eq!(c.nodes[n0].rack, 7);
        assert_eq!(c.racks(), 8);
        let d = c.attach_device(n0, DeviceProfile::ssd(1 << 34));
        assert_eq!(c.node_of(d), Some(n0));
        assert_eq!(c.rack_of(d), Some(7));
        assert_eq!(c.domain_devices(FailureDomain::Enclosure(n0)).len(), 2);
    }
}
