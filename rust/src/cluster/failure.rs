//! Failure injection (§3.2.1 High Availability).
//!
//! "Several hardware failures per second at Exascale": failures are the
//! norm. A [`FailureSchedule`] generates device/node failure events in
//! virtual time — either scripted (tests) or sampled from an exponential
//! inter-arrival model scaled by component count (the paper's
//! observation that failure rate scales with the number of units).
//!
//! The schedule is the **failure feed** of the recovery plane:
//! `Client::consume_failure_feed` (clovis) pops [`FailureSchedule::due`]
//! events, routes each through the HA subsystem's decision rules
//! (`mero::ha`), and executes the decided action — SNS repair or
//! proactive drain — as a Repair-class recovery session, with no
//! manual intervention. Drivers poll [`FailureSchedule::next_at`] to
//! decide how far to advance the clock between consumer passes, and
//! re-arm repaired devices with [`FailureSchedule::inject`].

use crate::cluster::DeviceId;
use crate::sim::clock::SimTime;
use crate::sim::rng::SimRng;

/// What failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// A storage device died (data on it is lost; SNS repair rebuilds).
    Device(DeviceId),
    /// A transient glitch (I/O error; retry succeeds). The HA subsystem
    /// must NOT trigger repair on isolated transients — it quantifies
    /// event sets over recent history (§3.2.1).
    Transient(DeviceId),
}

impl FailureKind {
    /// The device the event concerns (hard failure or transient).
    pub fn device(self) -> DeviceId {
        match self {
            FailureKind::Device(d) | FailureKind::Transient(d) => d,
        }
    }
}

/// A failure at a point in virtual time.
#[derive(Debug, Clone, Copy)]
pub struct FailureEvent {
    pub at: SimTime,
    pub kind: FailureKind,
}

/// A time-ordered failure schedule.
#[derive(Debug, Clone, Default)]
pub struct FailureSchedule {
    events: Vec<FailureEvent>,
    cursor: usize,
}

impl FailureSchedule {
    /// Scripted schedule (events need not be pre-sorted).
    pub fn scripted(mut events: Vec<FailureEvent>) -> Self {
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        FailureSchedule { events, cursor: 0 }
    }

    /// Sample a schedule: each of `devices` fails independently with
    /// exponential inter-arrival of mean `mtbf` seconds over `horizon`
    /// seconds of virtual time; a fraction `transient_ratio` of events
    /// are transient glitches rather than hard failures.
    pub fn sampled(
        devices: &[DeviceId],
        mtbf: f64,
        horizon: SimTime,
        transient_ratio: f64,
        rng: &mut SimRng,
    ) -> Self {
        let mut events = Vec::new();
        for &d in devices {
            let mut t = rng.gen_exp(mtbf);
            while t < horizon {
                let kind = if rng.gen_f64() < transient_ratio {
                    FailureKind::Transient(d)
                } else {
                    FailureKind::Device(d)
                };
                events.push(FailureEvent { at: t, kind });
                if matches!(kind, FailureKind::Device(_)) {
                    break; // hard-failed devices stay failed
                }
                t += rng.gen_exp(mtbf);
            }
        }
        Self::scripted(events)
    }

    /// Insert a future event, keeping time order. Used by the recovery
    /// plane: once SNS repair rebuilds a device and `replace_device`
    /// returns it to service, the device rejoins the failure
    /// population — callers re-arm it by injecting its next sampled
    /// failure after the repair completion time.
    pub fn inject(&mut self, ev: FailureEvent) {
        let pos = self.events[self.cursor..]
            .iter()
            .position(|e| e.at > ev.at)
            .map(|p| self.cursor + p)
            .unwrap_or(self.events.len());
        self.events.insert(pos, ev);
    }

    /// Pop all events with `at <= now`.
    pub fn due(&mut self, now: SimTime) -> Vec<FailureEvent> {
        let mut out = Vec::new();
        while self.cursor < self.events.len()
            && self.events[self.cursor].at <= now
        {
            out.push(self.events[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// Remaining event count.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.cursor
    }

    /// Virtual time of the next pending event (None when exhausted) —
    /// what a recovery-plane driver polls to decide how far to advance
    /// before the next `Client::consume_failure_feed` pass.
    pub fn next_at(&self) -> Option<SimTime> {
        self.events.get(self.cursor).map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_ordering_and_due() {
        let mut s = FailureSchedule::scripted(vec![
            FailureEvent { at: 5.0, kind: FailureKind::Device(1) },
            FailureEvent { at: 1.0, kind: FailureKind::Transient(0) },
        ]);
        assert_eq!(s.remaining(), 2);
        let d = s.due(2.0);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind, FailureKind::Transient(0));
        assert_eq!(s.due(10.0).len(), 1);
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn inject_keeps_time_order_and_device_accessor() {
        let mut s = FailureSchedule::scripted(vec![
            FailureEvent { at: 1.0, kind: FailureKind::Transient(0) },
            FailureEvent { at: 9.0, kind: FailureKind::Device(1) },
        ]);
        assert_eq!(s.due(2.0).len(), 1);
        // re-arm a repaired device between the remaining events
        s.inject(FailureEvent { at: 5.0, kind: FailureKind::Device(7) });
        let d = s.due(6.0);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind.device(), 7);
        assert_eq!(s.due(10.0)[0].kind.device(), 1);
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn sampled_respects_horizon_and_mtbf() {
        let mut rng = SimRng::new(42);
        let devs: Vec<DeviceId> = (0..100).collect();
        let s = FailureSchedule::sampled(&devs, 1000.0, 100.0, 0.5, &mut rng);
        // expected ~100 * 100/1000 = ~10 first-arrivals within horizon
        assert!(s.remaining() > 2 && s.remaining() < 40, "{}", s.remaining());
    }

    #[test]
    fn failure_rate_scales_with_devices() {
        let mut rng = SimRng::new(7);
        let few: Vec<DeviceId> = (0..10).collect();
        let many: Vec<DeviceId> = (0..1000).collect();
        let a = FailureSchedule::sampled(&few, 1000.0, 100.0, 0.0, &mut rng)
            .remaining();
        let b = FailureSchedule::sampled(&many, 1000.0, 100.0, 0.0, &mut rng)
            .remaining();
        assert!(b > 10 * a.max(1), "a={a} b={b}");
    }
}
