//! Failure injection (§3.2.1 High Availability).
//!
//! "Several hardware failures per second at Exascale": failures are the
//! norm. A [`FailureSchedule`] generates device/node failure events in
//! virtual time — either scripted (tests) or sampled from an exponential
//! inter-arrival model scaled by component count (the paper's
//! observation that failure rate scales with the number of units).
//!
//! The schedule is the **failure feed** of the recovery plane:
//! `Client::consume_failure_feed` (clovis) pops [`FailureSchedule::due`]
//! events, routes each through the HA subsystem's decision rules
//! (`mero::ha`), and executes the decided action — SNS repair or
//! proactive drain — as a Repair-class recovery session, with no
//! manual intervention. Drivers poll [`FailureSchedule::next_at`] to
//! decide how far to advance the clock between consumer passes, and
//! re-arm repaired devices with [`FailureSchedule::inject`].
//!
//! Beyond independent sampling, the schedule generates CORRELATED
//! failures over the cluster's failure topology
//! (`cluster::FailureDomain`): [`FailureSchedule::storm`] bursts hard
//! failures across one domain within a short window, and
//! [`FailureSchedule::sampled_with_storms`] overlays such bursts on the
//! independent background — both deterministic under [`SimRng`], so a
//! storm soak replays bit-identically from its seed.

use crate::cluster::DeviceId;
use crate::sim::clock::SimTime;
use crate::sim::rng::SimRng;

/// What failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// A storage device died (data on it is lost; SNS repair rebuilds).
    Device(DeviceId),
    /// A transient glitch (I/O error; retry succeeds). The HA subsystem
    /// must NOT trigger repair on isolated transients — it quantifies
    /// event sets over recent history (§3.2.1).
    Transient(DeviceId),
}

impl FailureKind {
    /// The device the event concerns (hard failure or transient).
    pub fn device(self) -> DeviceId {
        match self {
            FailureKind::Device(d) | FailureKind::Transient(d) => d,
        }
    }
}

/// A failure at a point in virtual time.
#[derive(Debug, Clone, Copy)]
pub struct FailureEvent {
    pub at: SimTime,
    pub kind: FailureKind,
}

/// A time-ordered failure schedule.
#[derive(Debug, Clone, Default)]
pub struct FailureSchedule {
    events: Vec<FailureEvent>,
    cursor: usize,
    /// Highest `now` any [`FailureSchedule::due`] pass has polled —
    /// the schedule's notion of the present. [`FailureSchedule::inject`]
    /// clamps below-watermark events up to it so nothing ever fires
    /// with a stale `at` in the past.
    watermark: SimTime,
}

impl FailureSchedule {
    /// Scripted schedule (events need not be pre-sorted).
    pub fn scripted(mut events: Vec<FailureEvent>) -> Self {
        events.sort_by(|a, b| a.at.total_cmp(&b.at));
        FailureSchedule { events, cursor: 0, watermark: 0.0 }
    }

    /// Sample a schedule: each of `devices` fails independently with
    /// exponential inter-arrival of mean `mtbf` seconds over `horizon`
    /// seconds of virtual time; a fraction `transient_ratio` of events
    /// are transient glitches rather than hard failures.
    pub fn sampled(
        devices: &[DeviceId],
        mtbf: f64,
        horizon: SimTime,
        transient_ratio: f64,
        rng: &mut SimRng,
    ) -> Self {
        let mut events = Vec::new();
        for &d in devices {
            let mut t = rng.gen_exp(mtbf);
            while t < horizon {
                let kind = if rng.gen_f64() < transient_ratio {
                    FailureKind::Transient(d)
                } else {
                    FailureKind::Device(d)
                };
                events.push(FailureEvent { at: t, kind });
                if matches!(kind, FailureKind::Device(_)) {
                    break; // hard-failed devices stay failed
                }
                t += rng.gen_exp(mtbf);
            }
        }
        Self::scripted(events)
    }

    /// Correlated burst: EVERY device of one failure domain hard-fails
    /// at a uniform offset within `[start, start + window)` — the
    /// simulated shape of a PDU trip or rack cooling loss
    /// (`cluster::FailureDomain` enumerates domain members via
    /// `Cluster::domain_devices`). Deterministic under `rng`: the same
    /// seed yields bit-identical event times.
    pub fn storm(
        devices: &[DeviceId],
        start: SimTime,
        window: SimTime,
        rng: &mut SimRng,
    ) -> Self {
        let window = window.max(0.0);
        let events = devices
            .iter()
            .map(|&d| FailureEvent {
                at: start + rng.gen_f64() * window,
                kind: FailureKind::Device(d),
            })
            .collect();
        Self::scripted(events)
    }

    /// Mixed sampler: the independent background of
    /// [`FailureSchedule::sampled`] overlaid with `storms` correlated
    /// bursts. Each burst picks one domain from `domains` (a list of
    /// device groups, e.g. `Cluster::domain_devices` per enclosure)
    /// and strikes it [`FailureSchedule::storm`]-style at a uniform
    /// start within the horizon. Deterministic under `rng`.
    #[allow(clippy::too_many_arguments)]
    pub fn sampled_with_storms(
        devices: &[DeviceId],
        mtbf: f64,
        horizon: SimTime,
        transient_ratio: f64,
        domains: &[Vec<DeviceId>],
        storms: usize,
        storm_window: SimTime,
        rng: &mut SimRng,
    ) -> Self {
        let mut all =
            Self::sampled(devices, mtbf, horizon, transient_ratio, rng);
        for _ in 0..storms {
            if domains.is_empty() {
                break;
            }
            let domain = &domains[rng.gen_index(domains.len())];
            let start =
                rng.gen_f64() * (horizon - storm_window).max(0.0);
            all.merge(Self::storm(domain, start, storm_window, rng));
        }
        all
    }

    /// Fold `other`'s pending events into this schedule, keeping time
    /// order (already-popped events of either side are dropped). Used
    /// to overlay a storm on a live feed mid-run.
    pub fn merge(&mut self, other: FailureSchedule) {
        let mut rest: Vec<FailureEvent> =
            self.events.split_off(self.cursor);
        rest.extend(other.events.into_iter().skip(other.cursor));
        // same stale-`at` rule as `inject`: nothing lands in the past
        for e in &mut rest {
            e.at = e.at.max(self.watermark);
        }
        rest.sort_by(|a, b| a.at.total_cmp(&b.at));
        self.events.extend(rest);
    }

    /// Insert a future event, keeping time order. Used by the recovery
    /// plane: once SNS repair rebuilds a device and `replace_device`
    /// returns it to service, the device rejoins the failure
    /// population — callers re-arm it by injecting its next sampled
    /// failure after the repair completion time.
    ///
    /// An event at or before the schedule's watermark (the highest
    /// `now` any [`FailureSchedule::due`] pass has seen) would
    /// otherwise land at the cursor and fire on the next pass with a
    /// stale `at` in the past; such events are clamped up to the
    /// watermark, so they still fire — at the present, not before it.
    pub fn inject(&mut self, ev: FailureEvent) {
        let ev = FailureEvent { at: ev.at.max(self.watermark), ..ev };
        let pos = self.events[self.cursor..]
            .iter()
            .position(|e| e.at > ev.at)
            .map(|p| self.cursor + p)
            .unwrap_or(self.events.len());
        self.events.insert(pos, ev);
    }

    /// Pop all events with `at <= now`.
    pub fn due(&mut self, now: SimTime) -> Vec<FailureEvent> {
        let mut out = Vec::new();
        self.due_into(now, &mut out);
        out
    }

    /// [`FailureSchedule::due`] into a caller-owned buffer (cleared
    /// first). §Perf (ISSUE 8): the storm-hardened consumer polls the
    /// feed once per loop iteration — recycling one batch buffer
    /// across iterations keeps a long soak from allocating a fresh
    /// Vec per poll.
    pub fn due_into(&mut self, now: SimTime, out: &mut Vec<FailureEvent>) {
        out.clear();
        while let Some(ev) = self.pop_next(now) {
            out.push(ev);
        }
    }

    /// Pop at most ONE due event (`at <= now`), advancing the
    /// watermark. The storm-hardened consumer drains events one at a
    /// time so escalations decided mid-batch stay in time order.
    pub fn pop_next(&mut self, now: SimTime) -> Option<FailureEvent> {
        self.watermark = self.watermark.max(now);
        if self.cursor < self.events.len()
            && self.events[self.cursor].at <= now
        {
            let ev = self.events[self.cursor];
            self.cursor += 1;
            return Some(ev);
        }
        None
    }

    /// The events [`FailureSchedule::due`] would pop at `now`, without
    /// consuming them or moving the watermark — drivers (the soak
    /// harness) size a batch before handing it to the consumer.
    pub fn peek_due(&self, now: SimTime) -> &[FailureEvent] {
        let mut end = self.cursor;
        while end < self.events.len() && self.events[end].at <= now {
            end += 1;
        }
        &self.events[self.cursor..end]
    }

    /// Highest `now` any [`FailureSchedule::due`] /
    /// [`FailureSchedule::pop_next`] pass has polled.
    pub fn watermark(&self) -> SimTime {
        self.watermark
    }

    /// Remaining event count.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.cursor
    }

    /// Virtual time of the next pending event (None when exhausted) —
    /// what a recovery-plane driver polls to decide how far to advance
    /// before the next `Client::consume_failure_feed` pass.
    pub fn next_at(&self) -> Option<SimTime> {
        self.events.get(self.cursor).map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_ordering_and_due() {
        let mut s = FailureSchedule::scripted(vec![
            FailureEvent { at: 5.0, kind: FailureKind::Device(1) },
            FailureEvent { at: 1.0, kind: FailureKind::Transient(0) },
        ]);
        assert_eq!(s.remaining(), 2);
        let d = s.due(2.0);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind, FailureKind::Transient(0));
        assert_eq!(s.due(10.0).len(), 1);
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn inject_keeps_time_order_and_device_accessor() {
        let mut s = FailureSchedule::scripted(vec![
            FailureEvent { at: 1.0, kind: FailureKind::Transient(0) },
            FailureEvent { at: 9.0, kind: FailureKind::Device(1) },
        ]);
        assert_eq!(s.due(2.0).len(), 1);
        // re-arm a repaired device between the remaining events
        s.inject(FailureEvent { at: 5.0, kind: FailureKind::Device(7) });
        let d = s.due(6.0);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind.device(), 7);
        assert_eq!(s.due(10.0)[0].kind.device(), 1);
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn sampled_respects_horizon_and_mtbf() {
        let mut rng = SimRng::new(42);
        let devs: Vec<DeviceId> = (0..100).collect();
        let s = FailureSchedule::sampled(&devs, 1000.0, 100.0, 0.5, &mut rng);
        // expected ~100 * 100/1000 = ~10 first-arrivals within horizon
        assert!(s.remaining() > 2 && s.remaining() < 40, "{}", s.remaining());
    }

    #[test]
    fn inject_clamps_stale_events_to_watermark() {
        let mut s = FailureSchedule::scripted(vec![
            FailureEvent { at: 1.0, kind: FailureKind::Transient(0) },
            FailureEvent { at: 9.0, kind: FailureKind::Device(1) },
        ]);
        assert_eq!(s.due(5.0).len(), 1);
        assert_eq!(s.watermark(), 5.0);
        // an event dated BEFORE the last polled time must not fire
        // with its stale `at`: it is clamped up to the watermark
        s.inject(FailureEvent { at: 1.5, kind: FailureKind::Device(7) });
        let d = s.due(5.0);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind.device(), 7);
        assert_eq!(d[0].at, 5.0, "stale at clamped to injection-time now");
        // future injections are untouched
        s.inject(FailureEvent { at: 7.0, kind: FailureKind::Device(8) });
        let d = s.due(10.0);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].at, 7.0);
        assert_eq!(d[1].at, 9.0);
    }

    #[test]
    fn storm_bursts_whole_domain_within_window() {
        let mut rng = SimRng::new(11);
        let domain = vec![3, 4, 5, 6];
        let s = FailureSchedule::storm(&domain, 100.0, 2.0, &mut rng);
        assert_eq!(s.remaining(), domain.len());
        let mut seen: Vec<DeviceId> = Vec::new();
        let mut t_prev = 0.0f64;
        for ev in s.clone().due(f64::INFINITY) {
            assert!(matches!(ev.kind, FailureKind::Device(_)), "hard only");
            assert!((100.0..102.0).contains(&ev.at), "at {}", ev.at);
            assert!(ev.at >= t_prev, "time-ordered");
            t_prev = ev.at;
            seen.push(ev.kind.device());
        }
        seen.sort_unstable();
        assert_eq!(seen, domain, "every domain member struck once");
    }

    #[test]
    fn storm_and_mixed_sampler_are_deterministic() {
        let bits = |s: &FailureSchedule| -> Vec<(u64, FailureKind)> {
            s.clone()
                .due(f64::INFINITY)
                .iter()
                .map(|e| (e.at.to_bits(), e.kind))
                .collect()
        };
        let a = FailureSchedule::storm(&[0, 1, 2], 5.0, 1.0, &mut SimRng::new(9));
        let b = FailureSchedule::storm(&[0, 1, 2], 5.0, 1.0, &mut SimRng::new(9));
        assert_eq!(bits(&a), bits(&b), "storm bit-identical under one seed");

        let devs: Vec<DeviceId> = (0..12).collect();
        let domains = vec![vec![0, 1, 2], vec![3, 4, 5], vec![6, 7, 8]];
        let mk = |seed| {
            FailureSchedule::sampled_with_storms(
                &devs, 5000.0, 1000.0, 0.4, &domains, 2, 3.0,
                &mut SimRng::new(seed),
            )
        };
        let (a, b) = (mk(33), mk(33));
        assert_eq!(bits(&a), bits(&b), "mixed sampler bit-identical");
        // the storms actually landed on top of the background
        assert!(a.remaining() >= 6, "{} events", a.remaining());
        assert_ne!(bits(&a), bits(&mk(34)), "seeds differ");
    }

    #[test]
    fn merge_interleaves_and_peek_matches_due() {
        let mut s = FailureSchedule::scripted(vec![
            FailureEvent { at: 2.0, kind: FailureKind::Transient(0) },
            FailureEvent { at: 8.0, kind: FailureKind::Device(1) },
        ]);
        assert_eq!(s.due(3.0).len(), 1);
        s.merge(FailureSchedule::scripted(vec![
            FailureEvent { at: 1.0, kind: FailureKind::Device(5) }, // stale
            FailureEvent { at: 6.0, kind: FailureKind::Device(6) },
        ]));
        let peeked: Vec<DeviceId> =
            s.peek_due(8.0).iter().map(|e| e.kind.device()).collect();
        assert_eq!(s.remaining(), 3);
        let popped: Vec<DeviceId> =
            s.due(8.0).iter().map(|e| e.kind.device()).collect();
        assert_eq!(peeked, popped);
        assert_eq!(popped, vec![5, 6, 1], "stale event clamped, order kept");
    }

    #[test]
    fn failure_rate_scales_with_devices() {
        let mut rng = SimRng::new(7);
        let few: Vec<DeviceId> = (0..10).collect();
        let many: Vec<DeviceId> = (0..1000).collect();
        let a = FailureSchedule::sampled(&few, 1000.0, 100.0, 0.0, &mut rng)
            .remaining();
        let b = FailureSchedule::sampled(&many, 1000.0, 100.0, 0.0, &mut rng)
            .remaining();
        assert!(b > 10 * a.max(1), "a={a} b={b}");
    }
}
