//! In-tree substrates for functionality the offline build cannot pull
//! from crates.io: JSON/TOML parsing, CLI argument handling, byte-size
//! helpers, compression. No paper section of its own — see
//! ARCHITECTURE.md §Module map.

pub mod alloc;
pub mod bytes;
pub mod cli;
pub mod compress;
pub mod json;
pub mod toml;

/// Round `n` up to the next multiple of `m` (m > 0).
pub fn round_up(n: u64, m: u64) -> u64 {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// True iff `n` is a power of two (and non-zero).
pub fn is_pow2(n: u64) -> bool {
    n != 0 && (n & (n - 1)) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn pow2_basics() {
        assert!(is_pow2(1));
        assert!(is_pow2(4096));
        assert!(!is_pow2(0));
        assert!(!is_pow2(3));
    }
}
