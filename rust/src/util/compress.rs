//! In-tree byte-run compression codec (offline substitute for a zlib
//! dependency). Used by compressed layouts ([`crate::mero::sns`]).
//!
//! The format is a token stream:
//! * `0x00 len:u16le <len bytes>` — literal run, `1..=65535` bytes
//! * `0x01 len:u16le byte` — `byte` repeated `len` times, `4..=65535`
//!
//! Scientific dumps (zero padding, repeated fields) compress well; the
//! worst case adds 3 bytes per 64 KiB of incompressible input. The
//! codec is byte-exact on round-trip, which is all the storage path
//! requires — ratio parity with zlib is not a goal.

/// Minimum run length worth encoding (below this a literal is smaller).
const MIN_RUN: usize = 4;
/// Maximum run/literal length one token can carry.
const MAX_LEN: usize = 65535;

/// Compress `data`; output is self-delimiting given its own length.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 4 + 16);
    let mut i = 0;
    let mut lit_start = 0;
    while i < data.len() {
        // measure the run starting at i
        let b = data[i];
        let mut j = i + 1;
        while j < data.len() && data[j] == b && j - i < MAX_LEN {
            j += 1;
        }
        let run = j - i;
        if run >= MIN_RUN {
            flush_literal(&mut out, &data[lit_start..i]);
            out.push(0x01);
            out.extend_from_slice(&(run as u16).to_le_bytes());
            out.push(b);
            i = j;
            lit_start = i;
        } else {
            i += run;
        }
    }
    flush_literal(&mut out, &data[lit_start..]);
    out
}

fn flush_literal(out: &mut Vec<u8>, lit: &[u8]) {
    for chunk in lit.chunks(MAX_LEN) {
        if chunk.is_empty() {
            continue;
        }
        out.push(0x00);
        out.extend_from_slice(&(chunk.len() as u16).to_le_bytes());
        out.extend_from_slice(chunk);
    }
}

/// Decompress a [`compress`] stream. Malformed/truncated input yields
/// the bytes decoded so far (callers bound the result by the recorded
/// original length).
pub fn decompress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 2);
    let mut i = 0;
    while i + 3 <= data.len() {
        let tag = data[i];
        let len = u16::from_le_bytes([data[i + 1], data[i + 2]]) as usize;
        i += 3;
        match tag {
            0x00 => {
                if i + len > data.len() {
                    break;
                }
                out.extend_from_slice(&data[i..i + len]);
                i += len;
            }
            0x01 => {
                if i >= data.len() {
                    break;
                }
                let b = data[i];
                i += 1;
                out.resize(out.len() + len, b);
            }
            _ => break,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::SimRng;

    #[test]
    fn roundtrip_compressible() {
        let mut data = vec![42u8; 64 * 1024];
        data[1000] = 7;
        let z = compress(&data);
        assert!(z.len() < data.len() / 8, "runs must compress well");
        assert_eq!(decompress(&z), data);
    }

    #[test]
    fn roundtrip_incompressible() {
        let mut data = vec![0u8; 100_000];
        SimRng::new(9).fill_bytes(&mut data);
        let z = compress(&data);
        assert!(z.len() < data.len() + 3 * (data.len() / MAX_LEN + 1) + 3);
        assert_eq!(decompress(&z), data);
    }

    #[test]
    fn roundtrip_edge_cases() {
        for data in [
            Vec::new(),
            vec![1u8],
            vec![5u8; 3],          // below MIN_RUN
            vec![5u8; MIN_RUN],    // exactly MIN_RUN
            vec![9u8; MAX_LEN + 10], // run split across tokens
        ] {
            assert_eq!(decompress(&compress(&data)), data);
        }
    }

    #[test]
    fn mixed_runs_and_literals() {
        let mut data = Vec::new();
        for i in 0..50u8 {
            data.extend_from_slice(&[i, i.wrapping_add(1), i.wrapping_add(2)]);
            data.resize(data.len() + (i as usize % 9), i);
        }
        assert_eq!(decompress(&compress(&data)), data);
    }

    #[test]
    fn truncated_input_is_safe() {
        let z = compress(&vec![3u8; 1000]);
        for cut in 0..z.len() {
            let partial = decompress(&z[..cut]);
            assert!(partial.len() <= 1000);
        }
    }
}
