//! Byte-size parsing and human-readable formatting.

/// Parse a size string: plain integers, or suffixed "KiB"/"MiB"/"GiB"/
/// "TiB" (binary) and "KB"/"MB"/"GB"/"TB" (decimal); fractional values
/// like "1.5GiB" allowed.
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    const UNITS: &[(&str, f64)] = &[
        ("TiB", 1024f64 * 1024.0 * 1024.0 * 1024.0),
        ("GiB", 1024f64 * 1024.0 * 1024.0),
        ("MiB", 1024f64 * 1024.0),
        ("KiB", 1024f64),
        ("TB", 1e12),
        ("GB", 1e9),
        ("MB", 1e6),
        ("KB", 1e3),
        ("B", 1.0),
    ];
    for (suffix, mult) in UNITS {
        if let Some(num) = s.strip_suffix(suffix) {
            let v: f64 = num.trim().parse().ok()?;
            return Some((v * mult) as u64);
        }
    }
    s.parse::<u64>().ok()
}

/// Format a byte count with a binary suffix, 1 decimal place.
pub fn fmt_size(n: u64) -> String {
    const STEPS: &[(&str, u64)] = &[
        ("TiB", 1 << 40),
        ("GiB", 1 << 30),
        ("MiB", 1 << 20),
        ("KiB", 1 << 10),
    ];
    for (suffix, div) in STEPS {
        if n >= *div {
            return format!("{:.1}{suffix}", n as f64 / *div as f64);
        }
    }
    format!("{n}B")
}

/// Format bytes/second.
pub fn fmt_bw(bytes_per_sec: f64) -> String {
    if bytes_per_sec >= 1e9 {
        format!("{:.2}GB/s", bytes_per_sec / 1e9)
    } else if bytes_per_sec >= 1e6 {
        format!("{:.1}MB/s", bytes_per_sec / 1e6)
    } else {
        format!("{:.0}KB/s", bytes_per_sec / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_size("4096"), Some(4096));
        assert_eq!(parse_size("64KiB"), Some(65536));
        assert_eq!(parse_size("1.5GiB"), Some(1610612736));
        assert_eq!(parse_size("12MB"), Some(12_000_000));
        assert_eq!(parse_size("bogus"), None);
    }

    #[test]
    fn fmt_sizes() {
        assert_eq!(fmt_size(512), "512B");
        assert_eq!(fmt_size(65536), "64.0KiB");
        assert_eq!(fmt_size(3 << 30), "3.0GiB");
    }
}
