//! TOML-subset parser for SAGE config files.
//!
//! Supports what our configs use: `[section]` and `[section.sub]`
//! headers, `key = value` with string / integer / float / boolean /
//! homogeneous-array values, `#` comments, and byte-size suffixes via
//! [`crate::util::bytes`] when read through [`TomlDoc::get_bytes`].

use std::collections::BTreeMap;

use crate::error::{Result, SageError};

/// A scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: dotted-section path -> key -> value.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) =
                line.strip_prefix('[').and_then(|s| s.strip_suffix(']'))
            {
                section = inner.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                SageError::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let value = parse_value(v.trim(), lineno + 1)?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    /// All section names (dotted paths).
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    /// Fetch `key` from `section` ("" = top level).
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|m| m.get(key))
    }

    /// String value or default.
    pub fn get_str(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    /// Integer value or default.
    pub fn get_i64(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    /// Float value or default.
    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// Byte size: accepts integers or strings like "64KiB", "1.5GiB".
    pub fn get_bytes(&self, section: &str, key: &str, default: u64) -> u64 {
        match self.get(section, key) {
            Some(TomlValue::Int(i)) => *i as u64,
            Some(TomlValue::Str(s)) => {
                super::bytes::parse_size(s).unwrap_or(default)
            }
            _ => default,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, lineno: usize) -> Result<TomlValue> {
    let err =
        || SageError::Config(format!("line {lineno}: bad value: {v}"));
    if let Some(inner) = v.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = inner
            .split(',')
            .map(|s| parse_value(s.trim(), lineno))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Arr(items));
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_config() {
        let doc = TomlDoc::parse(
            r#"
# SAGE testbed
name = "blackdog"

[tiers.hdd]
read_bw = "150MiB"       # sequential
capacity = 4_000_000_000
ratio = 0.5
devices = [1, 2]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "name", "?"), "blackdog");
        assert_eq!(
            doc.get_bytes("tiers.hdd", "read_bw", 0),
            150 * 1024 * 1024
        );
        assert_eq!(doc.get_i64("tiers.hdd", "capacity", 0), 4_000_000_000);
        assert_eq!(doc.get_f64("tiers.hdd", "ratio", 0.0), 0.5);
        assert_eq!(
            doc.get("tiers.hdd", "devices").unwrap(),
            &TomlValue::Arr(vec![TomlValue::Int(1), TomlValue::Int(2)])
        );
    }

    #[test]
    fn comments_and_errors() {
        assert!(TomlDoc::parse("key").is_err());
        assert!(TomlDoc::parse("k = @").is_err());
        let doc = TomlDoc::parse("k = \"a # not comment\" # real").unwrap();
        assert_eq!(doc.get_str("", "k", ""), "a # not comment");
    }
}
