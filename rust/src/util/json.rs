//! Minimal JSON parser — enough for `artifacts/manifest.json` and the
//! benchmark result files. Handles objects, arrays, strings (with basic
//! escapes), numbers, booleans and null. Not a general-purpose
//! replacement for serde_json; inputs are machine-generated and trusted.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Result, SageError};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(SageError::Config(format!(
                "trailing JSON at byte {}", p.i
            )));
        }
        Ok(v)
    }

    /// Object field access; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array elements; empty slice for non-arrays.
    pub fn items(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => &[],
        }
    }

    /// String payload.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric payload as u64 (floors).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
}

impl fmt::Display for Json {
    /// Serializes back to compact JSON (used by the bench result writer).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> SageError {
        SageError::Config(format!("JSON parse error at byte {}: {msg}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("eof escape"))?;
                    self.i += 1;
                    match e {
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'u' => {
                            // \uXXXX — BMP only, sufficient for our files
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            let n = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            s.push(char::from_u32(n).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        c => s.push(c as char),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self
            .peek()
            .map(|c| {
                c.is_ascii_digit()
                    || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            })
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let doc = r#"[{"name":"parity_k4","inputs":[{"shape":[4,16384],
            "dtype":"int32"}],"num_outputs":1}]"#;
        let j = Json::parse(doc).unwrap();
        let e = &j.items()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("parity_k4"));
        assert_eq!(
            e.get("inputs").unwrap().items()[0]
                .get("shape")
                .unwrap()
                .items()[1]
                .as_u64(),
            Some(16384)
        );
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" null ").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let doc = r#"{"a":[1,2.5,"x"],"b":{"c":null}}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }
}
