//! Tiny CLI argument parser (offline substitute for clap).
//!
//! Model: `prog <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`.

use std::collections::BTreeMap;

use crate::error::{Result, SageError};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` pairs; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
    /// Remaining positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.options.insert(stripped.to_string(), "true".into());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Typed option access with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.options
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Required option (error if absent or unparseable).
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T> {
        self.options
            .get(key)
            .ok_or_else(|| SageError::Config(format!("missing --{key}")))?
            .parse()
            .map_err(|_| SageError::Config(format!("bad value for --{key}")))
    }

    /// Boolean flag (present or "true").
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// String option.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: a bare `--flag` followed by a non-flag token consumes
        // it as a value (no declared-flag registry); pass positionals
        // first or use `--flag=true`.
        let a = parse("fig3 x y --testbed tegner --elems=1000 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig3"));
        assert_eq!(a.get_str("testbed", "?"), "tegner");
        assert_eq!(a.get::<u64>("elems", 0), 1000);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["x", "y"]);
    }

    #[test]
    fn defaults_and_require() {
        let a = parse("run");
        assert_eq!(a.get::<u32>("n", 42), 42);
        assert!(a.require::<u32>("n").is_err());
    }
}
