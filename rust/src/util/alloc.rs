//! Counting global allocator (§Perf, ISSUE 8): a thin wrapper around
//! the system allocator that tallies allocation count and requested
//! bytes in process-global atomics.
//!
//! The counters are **passive**: `sage` itself never installs the
//! allocator, so library users pay nothing and [`counts`] reports
//! zeros. A binary opts in with
//!
//! ```ignore
//! #[global_allocator]
//! static COUNTING: sage::util::alloc::CountingAlloc = CountingAlloc;
//! ```
//!
//! as `tests/alloc_budget.rs` does to pin the soak inner loop under a
//! fixed allocation budget, and as the soak harness's
//! [`SoakDiag`](crate::tools::soak::SoakDiag) surfaces when the
//! counters are live. Counter reads/writes use `Relaxed` ordering —
//! they are statistics, not synchronization — and `counts()` snapshots
//! are meaningful as *differences* around a single-threaded region.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// System-allocator wrapper that counts every `alloc`/`realloc`.
/// Zero-sized; install with `#[global_allocator]`.
pub struct CountingAlloc;

// SAFETY: delegates every operation verbatim to `System`; the only
// addition is relaxed atomic counter bumps, which cannot affect the
// returned pointers or layouts. This is the one sanctioned `unsafe`
// block under the crate-wide `#![deny(unsafe_code)]` — a global
// allocator cannot be expressed without it.
#[allow(unsafe_code)]
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Snapshot `(allocations, requested bytes)` since process start.
/// Both are 0 unless a binary installed [`CountingAlloc`] as its
/// global allocator; callers diff two snapshots around the region of
/// interest.
pub fn counts() -> (u64, u64) {
    (ALLOCS.load(Ordering::Relaxed), ALLOC_BYTES.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_are_monotonic_snapshots() {
        // the test binary does NOT install the allocator, so the
        // counters stay wherever they are (normally 0) — the contract
        // under test is that snapshots never go backwards
        let (a0, b0) = counts();
        let v = vec![0u8; 4096];
        std::hint::black_box(&v);
        let (a1, b1) = counts();
        assert!(a1 >= a0);
        assert!(b1 >= b0);
    }
}
