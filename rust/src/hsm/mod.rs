//! HSM: Hierarchical Storage Management (§3.2.3).
//!
//! "HSM is used to control the movement of data in the SAGE hierarchies
//! based on data usage." Implemented as an FDMI consumer: read/write
//! events feed a per-object heat map; a [`TieringPolicy`] decides
//! promotions (hot data up to NVRAM/flash) and demotions (cold data
//! down to disk/archive); [`Hsm::migrate`] executes movements with
//! real read+rewrite through the SNS layer.
//!
//! Policies (compared in the `ablate_hsm` bench):
//! * [`TieringPolicy::HeatWeighted`] — exponential-decay heat score
//!   (the SAGE approach: usage-driven)
//! * [`TieringPolicy::Fifo`] — demote the oldest untouched resident of
//!   each fast tier (one per planning cycle), promote on recent use
//! * [`TieringPolicy::Static`] — never move (placement-at-create only)
//!
//! ## Scheduler-driven migration (ISSUE 3 tentpole)
//!
//! [`Hsm::migrate`] no longer executes movements as a serial
//! read-then-write fold: [`Hsm::migrate_with`] batches the whole plan
//! onto ONE sharded `IoScheduler` — phase A dispatches every source
//! read up front, phase B rewrites each object at its own read
//! frontier — so a demotion to a slow SMR tier no longer blocks
//! promotions to NVRAM. `Client::migrate_with` wraps this in a one-op
//! Clovis session and emits `FdmiRecord::ObjectMigrated` per moved
//! object; stage `Session::migrate` next to writes/ships to overlap a
//! background migration with foreground traffic on shared shards
//! (ISSUE 4 session API).

use std::collections::BTreeMap;

use crate::clovis::fdmi::FdmiRecord;
use crate::error::Result;
use crate::mero::layout::Layout;
use crate::mero::object::ObjectId;
use crate::mero::MeroStore;
use crate::sim::clock::SimTime;
use crate::sim::device::DeviceKind;
use crate::sim::sched::{IoScheduler, TrafficClass};

/// Per-object usage heat with exponential decay.
#[derive(Debug, Clone)]
pub struct Heat {
    pub score: f64,
    pub last_touch: SimTime,
    pub created: SimTime,
    pub tier: DeviceKind,
    pub size: u64,
}

/// Tiering policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TieringPolicy {
    HeatWeighted,
    Fifo,
    Static,
}

/// A planned data movement.
#[derive(Debug, Clone, PartialEq)]
pub struct Migration {
    pub obj: ObjectId,
    pub from: DeviceKind,
    pub to: DeviceKind,
}

/// Heat tracking + policy + migration executor.
pub struct Hsm {
    pub policy: TieringPolicy,
    /// Heat half-life, seconds of virtual time.
    pub half_life: f64,
    /// Promote when score exceeds this.
    pub promote_threshold: f64,
    /// Demote when score falls below this.
    pub demote_threshold: f64,
    heat: BTreeMap<ObjectId, Heat>,
    /// Migrations completed by the most recent [`Hsm::migrate_with`]
    /// call (in execution order; survives a mid-plan error, so callers
    /// can publish exactly what really moved).
    last_migrated: Vec<Migration>,
    pub migrations_run: u64,
    pub bytes_moved: u64,
}

impl Hsm {
    /// HSM with a policy and default thresholds.
    pub fn new(policy: TieringPolicy) -> Self {
        Hsm {
            policy,
            half_life: 60.0,
            promote_threshold: 3.0,
            demote_threshold: 0.2,
            heat: BTreeMap::new(),
            last_migrated: Vec::new(),
            migrations_run: 0,
            bytes_moved: 0,
        }
    }

    /// Ingest FDMI records (drained from the Clovis bus) to update heat.
    pub fn observe(&mut self, records: &[FdmiRecord], store: &MeroStore) {
        for rec in records {
            let obj = rec.object();
            let at = rec.at();
            match rec {
                FdmiRecord::ObjectDeleted { .. } => {
                    self.heat.remove(&obj);
                }
                FdmiRecord::ObjectCreated { .. } => {
                    let (tier, size) = store
                        .object(obj)
                        .map(|o| (o.layout.tier(), o.size))
                        .unwrap_or((DeviceKind::Ssd, 0));
                    self.heat.insert(obj, Heat {
                        score: 1.0,
                        last_touch: at,
                        created: at,
                        tier,
                        size,
                    });
                }
                FdmiRecord::ObjectWritten { len, .. }
                | FdmiRecord::ObjectRead { len, .. } => {
                    let size = store.object(obj).map(|o| o.size).unwrap_or(0);
                    let e = self.heat.entry(obj).or_insert_with(|| Heat {
                        score: 0.0,
                        last_touch: at,
                        created: at,
                        tier: store
                            .object(obj)
                            .map(|o| o.layout.tier())
                            .unwrap_or(DeviceKind::Ssd),
                        size,
                    });
                    // decay then bump (weight by touched fraction)
                    let dt = (at - e.last_touch).max(0.0);
                    e.score *= 0.5f64.powf(dt / self.half_life);
                    e.score += 1.0 + (*len as f64 / (1 << 20) as f64).min(4.0);
                    e.last_touch = at;
                    e.size = size.max(e.size);
                }
                FdmiRecord::ObjectMigrated { to_tier, .. } => {
                    // keep the tracked tier in sync for consumers that
                    // did not run the migration themselves (data
                    // movement is not usage: no heat bump)
                    if let Some(h) = self.heat.get_mut(&obj) {
                        if let Some(kind) = storage_kind_for_tier(*to_tier) {
                            h.tier = kind;
                        }
                    }
                }
            }
        }
    }

    /// Tier the HSM currently tracks `obj` on (None if untracked).
    pub fn tier_of(&self, obj: ObjectId) -> Option<DeviceKind> {
        self.heat.get(&obj).map(|h| h.tier)
    }

    /// Current heat score of an object, decayed to `now`.
    pub fn score(&self, obj: ObjectId, now: SimTime) -> f64 {
        self.heat
            .get(&obj)
            .map(|h| h.score * 0.5f64.powf((now - h.last_touch).max(0.0) / self.half_life))
            .unwrap_or(0.0)
    }

    /// Decide migrations under the configured policy.
    pub fn plan(&self, now: SimTime) -> Vec<Migration> {
        let mut plan = Vec::new();
        match self.policy {
            TieringPolicy::Static => {}
            TieringPolicy::HeatWeighted => {
                for (&obj, h) in &self.heat {
                    let s = self.score(obj, now);
                    if s >= self.promote_threshold {
                        if let Some(up) = promote_target(h.tier) {
                            plan.push(Migration { obj, from: h.tier, to: up });
                        }
                    } else if s <= self.demote_threshold {
                        if let Some(down) = demote_target(h.tier) {
                            plan.push(Migration { obj, from: h.tier, to: down });
                        }
                    }
                }
            }
            TieringPolicy::Fifo => {
                // promote anything touched within the last half-life
                // window; demote the OLDEST (first-in) untouched
                // resident of each fast tier — one per tier per
                // planning cycle, regardless of absolute age
                let mut oldest: BTreeMap<DeviceKind, (ObjectId, SimTime)> =
                    BTreeMap::new();
                for (&obj, h) in &self.heat {
                    if now - h.last_touch < self.half_life {
                        if let Some(up) = promote_target(h.tier) {
                            plan.push(Migration { obj, from: h.tier, to: up });
                        }
                        continue;
                    }
                    if demote_target(h.tier).is_none() {
                        continue; // already on the slowest tier
                    }
                    let e = oldest.entry(h.tier).or_insert((obj, h.created));
                    // deterministic: earliest created wins, object id
                    // breaks ties
                    if h.created < e.1 || (h.created == e.1 && obj < e.0) {
                        *e = (obj, h.created);
                    }
                }
                for (tier, (obj, _)) in oldest {
                    if let Some(down) = demote_target(tier) {
                        plan.push(Migration { obj, from: tier, to: down });
                    }
                }
            }
        }
        // objects appear at most once; the heat map and the FIFO
        // per-tier fold are ordered (BTreeMap — `no-hash-iteration`),
        // and this sort additionally gives plan() a total order by
        // object id regardless of which policy branch produced it
        plan.sort_by_key(|m| m.obj);
        plan
    }

    /// Execute migrations as a self-contained batch (private
    /// scheduler): see [`Hsm::migrate_with`]. Returns completion time.
    /// Data integrity invariant: bytes before == bytes after (tested
    /// in prop_invariants and `tests/prop_repair.rs`).
    pub fn migrate(
        &mut self,
        store: &mut MeroStore,
        plan: &[Migration],
        now: SimTime,
    ) -> Result<SimTime> {
        let mut sched = IoScheduler::new();
        self.migrate_with(store, plan, now, &mut sched)
    }

    /// Execute the whole migration plan as ONE scheduler-driven batch
    /// (scheduler-driven recovery plane): phase A reads every source
    /// object through the caller's group scheduler — all reads
    /// dispatch at `now`, so a demotion to a slow SMR tier no longer
    /// blocks promotions to NVRAM — then phase B releases the old
    /// placements, retargets each layout, and rewrites through the
    /// same scheduler at each object's own read frontier. Returns the
    /// batch completion (max over the moved objects' write
    /// completions). Peak memory is the plan's total byte size (every
    /// staged source is held until its rewrite) — the price of the
    /// overlap: rewriting each object as soon as its read returns
    /// would queue later sources' reads behind earlier rewrites and
    /// re-serialize the fold.
    ///
    /// All migration I/O dispatches as [`TrafficClass::Migration`]
    /// (§3.2.1 repair throttling): a QoS-carrying scheduler — every
    /// Clovis session's — caps tiering traffic at its configured share
    /// of each device so data movement never starves foreground I/O.
    /// The private scheduler of [`Hsm::migrate`] enforces no split.
    pub fn migrate_with(
        &mut self,
        store: &mut MeroStore,
        plan: &[Migration],
        now: SimTime,
        sched: &mut IoScheduler,
    ) -> Result<SimTime> {
        sched.with_class(TrafficClass::Migration, |sched| {
            self.migrate_with_inner(store, plan, now, sched)
        })
    }

    fn migrate_with_inner(
        &mut self,
        store: &mut MeroStore,
        plan: &[Migration],
        now: SimTime,
        sched: &mut IoScheduler,
    ) -> Result<SimTime> {
        // A migration whose source read has completed (in plan order,
        // so pool allocation matches the serial fold exactly).
        struct Staged {
            m: Migration,
            size: u64,
            data: Option<Vec<u8>>,
            t_read: SimTime,
        }

        // ---- phase A: batched source reads --------------------------
        self.last_migrated.clear();
        let mut staged: Vec<Staged> = Vec::new();
        for m in plan {
            let size = store.object(m.obj)?.size;
            if size == 0 {
                continue;
            }
            let is_real = store.object(m.obj)?.real_blocks() > 0;
            let (data, t_read) = if is_real {
                let (d, tr) =
                    crate::mero::sns::read_with(store, m.obj, 0, size, now, sched)?;
                (Some(d), tr)
            } else {
                (
                    None,
                    crate::mero::sns::read_phantom_with(
                        store, m.obj, 0, size, now, sched,
                    )?,
                )
            };
            staged.push(Staged { m: m.clone(), size, data, t_read });
        }

        // ---- phase B: release, retarget, rewrite --------------------
        let mut t = now;
        for s in staged {
            // release old placements
            let old_units: Vec<_> =
                store.object(s.m.obj)?.placed_units().copied().collect();
            for u in &old_units {
                store.pools.release(&mut store.cluster, u.device, u.size);
            }
            // retarget the layout and clear placements by re-creating
            // the unit map through a fresh write
            {
                let obj = store.object_mut(s.m.obj)?;
                obj.layout = retier(&obj.layout, s.m.to);
                obj.clear_placements(); // next write re-places on `to`
            }
            let t_write = match s.data {
                Some(d) => crate::mero::sns::write_with(
                    store,
                    s.m.obj,
                    0,
                    crate::mero::sns::Payload::Owned(d),
                    s.t_read,
                    None,
                    sched,
                )?,
                None => crate::mero::sns::write_with(
                    store,
                    s.m.obj,
                    0,
                    crate::mero::sns::Payload::Phantom(s.size),
                    s.t_read,
                    None,
                    sched,
                )?,
            };
            t = t.max(t_write);
            self.migrations_run += 1;
            self.bytes_moved += s.size;
            if let Some(h) = self.heat.get_mut(&s.m.obj) {
                h.tier = s.m.to;
            }
            self.last_migrated.push(s.m);
        }
        Ok(t)
    }

    /// Migrations actually completed by the most recent
    /// [`Hsm::migrate_with`] call, in execution order — the source of
    /// truth for what moved (zero-size plan entries are skipped; on a
    /// mid-plan error the completed prefix is preserved), consumed by
    /// `Client::migrate_with` to publish `ObjectMigrated` records.
    pub fn last_migrated(&self) -> &[Migration] {
        &self.last_migrated
    }

    /// Number of tracked objects.
    pub fn tracked(&self) -> usize {
        self.heat.len()
    }
}

/// Next tier up (faster), if any.
pub fn promote_target(t: DeviceKind) -> Option<DeviceKind> {
    match t {
        DeviceKind::Smr => Some(DeviceKind::Hdd),
        DeviceKind::Hdd | DeviceKind::LustreOst => Some(DeviceKind::Ssd),
        DeviceKind::Ssd => Some(DeviceKind::Nvram),
        _ => None,
    }
}

/// Storage tier index → device kind: the inverse of
/// [`DeviceKind::tier`] over the HSM-managed storage tiers, used to
/// decode `FdmiRecord::ObjectMigrated` tier stamps. Tier 3 maps to
/// HDD (Lustre OSTs share the index but are not an HSM target); DRAM
/// (tier 0) is not a storage pool.
pub fn storage_kind_for_tier(tier: u8) -> Option<DeviceKind> {
    match tier {
        1 => Some(DeviceKind::Nvram),
        2 => Some(DeviceKind::Ssd),
        3 => Some(DeviceKind::Hdd),
        4 => Some(DeviceKind::Smr),
        _ => None,
    }
}

/// Next tier down (bigger/cheaper), if any.
pub fn demote_target(t: DeviceKind) -> Option<DeviceKind> {
    match t {
        DeviceKind::Nvram => Some(DeviceKind::Ssd),
        DeviceKind::Ssd => Some(DeviceKind::Hdd),
        DeviceKind::Hdd | DeviceKind::LustreOst => Some(DeviceKind::Smr),
        _ => None,
    }
}

/// Clone a layout onto a different tier.
fn retier(l: &Layout, to: DeviceKind) -> Layout {
    match l {
        Layout::Raid { data, parity, unit, .. } => Layout::Raid {
            data: *data,
            parity: *parity,
            unit: *unit,
            tier: to,
        },
        Layout::Mirror { copies, .. } => Layout::Mirror { copies: *copies, tier: to },
        Layout::Compressed { inner } => Layout::Compressed {
            inner: Box::new(retier(inner, to)),
        },
        Layout::Composite { extents } => Layout::Composite {
            extents: extents
                .iter()
                .map(|(o, l2, inner)| (*o, *l2, retier(inner, to)))
                .collect(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;

    #[test]
    fn heat_decays() {
        let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
        let store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        hsm.observe(
            &[FdmiRecord::ObjectWritten {
                obj: ObjectId(1),
                offset: 0,
                len: 1 << 20,
                at: 0.0,
            }],
            &store,
        );
        let hot = hsm.score(ObjectId(1), 1.0);
        let cooled = hsm.score(ObjectId(1), 600.0);
        assert!(hot > 1.0);
        assert!(cooled < 0.01 * hot);
    }

    #[test]
    fn hot_objects_promote_cold_demote() {
        let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
        let store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        // hot object: many touches
        for i in 0..10 {
            hsm.observe(
                &[FdmiRecord::ObjectRead {
                    obj: ObjectId(1),
                    offset: 0,
                    len: 4096,
                    at: i as f64,
                }],
                &store,
            );
        }
        // cold object: one old touch
        hsm.observe(
            &[FdmiRecord::ObjectRead {
                obj: ObjectId(2),
                offset: 0,
                len: 4096,
                at: 0.0,
            }],
            &store,
        );
        let plan = hsm.plan(500.0);
        let promoted: Vec<_> =
            plan.iter().filter(|m| m.to.tier() < m.from.tier()).collect();
        let demoted: Vec<_> =
            plan.iter().filter(|m| m.to.tier() > m.from.tier()).collect();
        // at t=500 the hot object has cooled too; re-plan right after use
        let plan_hot = hsm.plan(10.0);
        assert!(
            plan_hot.iter().any(|m| m.obj == ObjectId(1)
                && m.to.tier() < m.from.tier()),
            "hot object should promote: {plan_hot:?}"
        );
        assert!(
            demoted.iter().any(|m| m.obj == ObjectId(2)),
            "cold object should demote: {plan:?} {promoted:?}"
        );
    }

    #[test]
    fn static_policy_never_moves() {
        let mut hsm = Hsm::new(TieringPolicy::Static);
        let store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        for i in 0..20 {
            hsm.observe(
                &[FdmiRecord::ObjectRead {
                    obj: ObjectId(1),
                    offset: 0,
                    len: 1 << 20,
                    at: i as f64,
                }],
                &store,
            );
        }
        assert!(hsm.plan(21.0).is_empty());
    }

    #[test]
    fn migration_preserves_bytes_and_changes_tier() {
        let mut store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        let obj = store
            .create_object(4096, Layout::default())
            .unwrap();
        let data: Vec<u8> = (0..4 * 65536u32).map(|i| (i % 251) as u8).collect();
        store.write_object(obj, 0, &data, 0.0, None).unwrap();
        let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
        let plan = vec![Migration {
            obj,
            from: DeviceKind::Ssd,
            to: DeviceKind::Nvram,
        }];
        let t = hsm.migrate(&mut store, &plan, 1.0).unwrap();
        assert!(t > 1.0);
        assert_eq!(store.object(obj).unwrap().layout.tier(), DeviceKind::Nvram);
        let (back, _) = store.read_object(obj, 0, data.len() as u64, t).unwrap();
        assert_eq!(back, data, "migration must not lose bytes");
        assert_eq!(hsm.migrations_run, 1);
    }

    #[test]
    fn fifo_demotes_only_the_oldest_resident_per_tier() {
        // the pinned FIFO semantics: ONE demotion per fast tier per
        // planning cycle — the first-in (oldest-created) untouched
        // resident — not every object past an age threshold
        let mut hsm = Hsm::new(TieringPolicy::Fifo);
        let store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        for (i, at) in [(1u64, 0.0), (2, 5.0), (3, 10.0)] {
            hsm.observe(
                &[FdmiRecord::ObjectCreated { obj: ObjectId(i), at }],
                &store,
            );
        }
        let plan = hsm.plan(1000.0);
        let demotions: Vec<_> =
            plan.iter().filter(|m| m.to.tier() > m.from.tier()).collect();
        assert_eq!(
            demotions.len(),
            1,
            "one demotion per tier per cycle: {plan:?}"
        );
        assert_eq!(demotions[0].obj, ObjectId(1), "oldest resident first");
        assert_eq!(demotions[0].from, DeviceKind::Ssd);
        assert_eq!(demotions[0].to, DeviceKind::Hdd);
        // a recently-touched resident promotes instead of demoting
        hsm.observe(
            &[FdmiRecord::ObjectRead {
                obj: ObjectId(3),
                offset: 0,
                len: 4096,
                at: 1000.0,
            }],
            &store,
        );
        let plan = hsm.plan(1001.0);
        assert!(plan
            .iter()
            .any(|m| m.obj == ObjectId(3) && m.to == DeviceKind::Nvram));
        assert!(plan
            .iter()
            .all(|m| !(m.obj == ObjectId(3) && m.to.tier() > 2)));
    }

    #[test]
    fn observe_object_migrated_updates_tracked_tier() {
        // an HSM instance that did NOT run the migration itself stays
        // consistent by consuming the ObjectMigrated feed
        let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
        let store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        hsm.observe(
            &[FdmiRecord::ObjectCreated { obj: ObjectId(1), at: 0.0 }],
            &store,
        );
        assert_eq!(hsm.tier_of(ObjectId(1)), Some(DeviceKind::Ssd));
        let before = hsm.score(ObjectId(1), 1.0);
        hsm.observe(
            &[FdmiRecord::ObjectMigrated {
                obj: ObjectId(1),
                from_tier: DeviceKind::Ssd.tier(),
                to_tier: DeviceKind::Nvram.tier(),
                at: 1.0,
            }],
            &store,
        );
        assert_eq!(hsm.tier_of(ObjectId(1)), Some(DeviceKind::Nvram));
        // data movement is not usage: the heat score did not bump
        assert!(hsm.score(ObjectId(1), 1.0) <= before + 1e-12);
    }

    #[test]
    fn batched_migrate_with_shares_one_scheduler() {
        // two migrations in one plan: reads dispatch up front, writes
        // stream behind them, nothing left pending on the scheduler
        let mut store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        let mut objs = Vec::new();
        for i in 0..2u8 {
            let o = store.create_object(4096, Layout::default()).unwrap();
            let data = vec![i + 1; 4 * 65536];
            store.write_object(o, 0, &data, 0.0, None).unwrap();
            objs.push((o, data));
        }
        let plan = vec![
            Migration { obj: objs[0].0, from: DeviceKind::Ssd, to: DeviceKind::Nvram },
            Migration { obj: objs[1].0, from: DeviceKind::Ssd, to: DeviceKind::Hdd },
        ];
        let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
        let mut sched = IoScheduler::new();
        let t = hsm.migrate_with(&mut store, &plan, 1.0, &mut sched).unwrap();
        assert!(t > 1.0);
        assert_eq!(sched.pending(), 0);
        assert!(sched.ios() > 0, "all migration I/O rides the scheduler");
        assert_eq!(hsm.migrations_run, 2);
        for (o, data) in &objs {
            let (back, _) =
                store.read_object(*o, 0, data.len() as u64, t).unwrap();
            assert_eq!(&back, data, "batched migration preserves bytes");
        }
        assert_eq!(
            store.object(objs[0].0).unwrap().layout.tier(),
            DeviceKind::Nvram
        );
        assert_eq!(
            store.object(objs[1].0).unwrap().layout.tier(),
            DeviceKind::Hdd
        );
    }

    #[test]
    fn tier_ladder_is_consistent() {
        // promote then demote returns to the same tier (where defined)
        for t in [DeviceKind::Ssd, DeviceKind::Hdd] {
            let up = promote_target(t).unwrap();
            assert_eq!(demote_target(up), Some(t));
        }
        assert_eq!(promote_target(DeviceKind::Nvram), None);
        assert_eq!(demote_target(DeviceKind::Smr), None);
    }
}
