//! HSM: Hierarchical Storage Management (§3.2.3).
//!
//! "HSM is used to control the movement of data in the SAGE hierarchies
//! based on data usage." Implemented as an FDMI consumer: read/write
//! events feed a per-object heat map; a [`TieringPolicy`] decides
//! promotions (hot data up to NVRAM/flash) and demotions (cold data
//! down to disk/archive); the [`MigrationEngine`] executes movements
//! with real read+rewrite through the SNS layer.
//!
//! Policies (compared in the `ablate_hsm` bench):
//! * [`TieringPolicy::HeatWeighted`] — exponential-decay heat score
//!   (the SAGE approach: usage-driven)
//! * [`TieringPolicy::Fifo`] — demote oldest first, promote on any use
//! * [`TieringPolicy::Static`] — never move (placement-at-create only)

use std::collections::HashMap;

use crate::clovis::fdmi::FdmiRecord;
use crate::error::Result;
use crate::mero::layout::Layout;
use crate::mero::object::ObjectId;
use crate::mero::MeroStore;
use crate::sim::clock::SimTime;
use crate::sim::device::DeviceKind;

/// Per-object usage heat with exponential decay.
#[derive(Debug, Clone)]
pub struct Heat {
    pub score: f64,
    pub last_touch: SimTime,
    pub created: SimTime,
    pub tier: DeviceKind,
    pub size: u64,
}

/// Tiering policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TieringPolicy {
    HeatWeighted,
    Fifo,
    Static,
}

/// A planned data movement.
#[derive(Debug, Clone, PartialEq)]
pub struct Migration {
    pub obj: ObjectId,
    pub from: DeviceKind,
    pub to: DeviceKind,
}

/// Heat tracking + policy + migration executor.
pub struct Hsm {
    pub policy: TieringPolicy,
    /// Heat half-life, seconds of virtual time.
    pub half_life: f64,
    /// Promote when score exceeds this.
    pub promote_threshold: f64,
    /// Demote when score falls below this.
    pub demote_threshold: f64,
    heat: HashMap<ObjectId, Heat>,
    pub migrations_run: u64,
    pub bytes_moved: u64,
}

impl Hsm {
    /// HSM with a policy and default thresholds.
    pub fn new(policy: TieringPolicy) -> Self {
        Hsm {
            policy,
            half_life: 60.0,
            promote_threshold: 3.0,
            demote_threshold: 0.2,
            heat: HashMap::new(),
            migrations_run: 0,
            bytes_moved: 0,
        }
    }

    /// Ingest FDMI records (drained from the Clovis bus) to update heat.
    pub fn observe(&mut self, records: &[FdmiRecord], store: &MeroStore) {
        for rec in records {
            let obj = rec.object();
            let at = rec.at();
            match rec {
                FdmiRecord::ObjectDeleted { .. } => {
                    self.heat.remove(&obj);
                }
                FdmiRecord::ObjectCreated { .. } => {
                    let (tier, size) = store
                        .object(obj)
                        .map(|o| (o.layout.tier(), o.size))
                        .unwrap_or((DeviceKind::Ssd, 0));
                    self.heat.insert(obj, Heat {
                        score: 1.0,
                        last_touch: at,
                        created: at,
                        tier,
                        size,
                    });
                }
                FdmiRecord::ObjectWritten { len, .. }
                | FdmiRecord::ObjectRead { len, .. } => {
                    let size = store.object(obj).map(|o| o.size).unwrap_or(0);
                    let e = self.heat.entry(obj).or_insert(Heat {
                        score: 0.0,
                        last_touch: at,
                        created: at,
                        tier: store
                            .object(obj)
                            .map(|o| o.layout.tier())
                            .unwrap_or(DeviceKind::Ssd),
                        size,
                    });
                    // decay then bump (weight by touched fraction)
                    let dt = (at - e.last_touch).max(0.0);
                    e.score *= 0.5f64.powf(dt / self.half_life);
                    e.score += 1.0 + (*len as f64 / (1 << 20) as f64).min(4.0);
                    e.last_touch = at;
                    e.size = size.max(e.size);
                }
                FdmiRecord::ObjectMigrated { .. } => {}
            }
        }
    }

    /// Current heat score of an object, decayed to `now`.
    pub fn score(&self, obj: ObjectId, now: SimTime) -> f64 {
        self.heat
            .get(&obj)
            .map(|h| h.score * 0.5f64.powf((now - h.last_touch).max(0.0) / self.half_life))
            .unwrap_or(0.0)
    }

    /// Decide migrations under the configured policy.
    pub fn plan(&self, now: SimTime) -> Vec<Migration> {
        let mut plan = Vec::new();
        match self.policy {
            TieringPolicy::Static => {}
            TieringPolicy::HeatWeighted => {
                for (&obj, h) in &self.heat {
                    let s = self.score(obj, now);
                    if s >= self.promote_threshold {
                        if let Some(up) = promote_target(h.tier) {
                            plan.push(Migration { obj, from: h.tier, to: up });
                        }
                    } else if s <= self.demote_threshold {
                        if let Some(down) = demote_target(h.tier) {
                            plan.push(Migration { obj, from: h.tier, to: down });
                        }
                    }
                }
            }
            TieringPolicy::Fifo => {
                // demote the oldest resident of each fast tier; promote
                // anything touched in the last half-life window
                for (&obj, h) in &self.heat {
                    if now - h.last_touch < self.half_life {
                        if let Some(up) = promote_target(h.tier) {
                            plan.push(Migration { obj, from: h.tier, to: up });
                        }
                    } else if now - h.created > 4.0 * self.half_life {
                        if let Some(down) = demote_target(h.tier) {
                            plan.push(Migration { obj, from: h.tier, to: down });
                        }
                    }
                }
            }
        }
        plan.sort_by_key(|m| m.obj);
        plan
    }

    /// Execute migrations: read through SNS, rewrite with the target
    /// tier's layout, release the old placement. Returns completion
    /// time. Data integrity invariant: bytes before == bytes after
    /// (tested in prop_invariants).
    pub fn migrate(
        &mut self,
        store: &mut MeroStore,
        plan: &[Migration],
        now: SimTime,
    ) -> Result<SimTime> {
        let mut t = now;
        for m in plan {
            let size = store.object(m.obj)?.size;
            if size == 0 {
                continue;
            }
            let is_real = store.object(m.obj)?.real_blocks() > 0;
            let (data, t_read) = if is_real {
                let (d, tr) = crate::mero::sns::read(store, m.obj, 0, size, t)?;
                (Some(d), tr)
            } else {
                (None, crate::mero::sns::read_phantom(store, m.obj, 0, size, t)?)
            };
            // release old placements
            let old_units: Vec<_> =
                store.object(m.obj)?.placed_units().copied().collect();
            for u in &old_units {
                store.pools.release(&mut store.cluster, u.device, u.size);
            }
            // retarget the layout and clear placements by re-creating
            // the unit map through a fresh write
            {
                let obj = store.object_mut(m.obj)?;
                obj.layout = retier(&obj.layout, m.to);
                obj.clear_placements(); // next write re-places on `to`
            }
            let t_write = match data {
                Some(d) => crate::mero::sns::write(
                    store,
                    m.obj,
                    0,
                    crate::mero::sns::Payload::Real(&d),
                    t_read,
                    None,
                )?,
                None => crate::mero::sns::write(
                    store,
                    m.obj,
                    0,
                    crate::mero::sns::Payload::Phantom(size),
                    t_read,
                    None,
                )?,
            };
            t = t_write;
            self.migrations_run += 1;
            self.bytes_moved += size;
            if let Some(h) = self.heat.get_mut(&m.obj) {
                h.tier = m.to;
            }
        }
        Ok(t)
    }

    /// Number of tracked objects.
    pub fn tracked(&self) -> usize {
        self.heat.len()
    }
}

/// Next tier up (faster), if any.
pub fn promote_target(t: DeviceKind) -> Option<DeviceKind> {
    match t {
        DeviceKind::Smr => Some(DeviceKind::Hdd),
        DeviceKind::Hdd | DeviceKind::LustreOst => Some(DeviceKind::Ssd),
        DeviceKind::Ssd => Some(DeviceKind::Nvram),
        _ => None,
    }
}

/// Next tier down (bigger/cheaper), if any.
pub fn demote_target(t: DeviceKind) -> Option<DeviceKind> {
    match t {
        DeviceKind::Nvram => Some(DeviceKind::Ssd),
        DeviceKind::Ssd => Some(DeviceKind::Hdd),
        DeviceKind::Hdd | DeviceKind::LustreOst => Some(DeviceKind::Smr),
        _ => None,
    }
}

/// Clone a layout onto a different tier.
fn retier(l: &Layout, to: DeviceKind) -> Layout {
    match l {
        Layout::Raid { data, parity, unit, .. } => Layout::Raid {
            data: *data,
            parity: *parity,
            unit: *unit,
            tier: to,
        },
        Layout::Mirror { copies, .. } => Layout::Mirror { copies: *copies, tier: to },
        Layout::Compressed { inner } => Layout::Compressed {
            inner: Box::new(retier(inner, to)),
        },
        Layout::Composite { extents } => Layout::Composite {
            extents: extents
                .iter()
                .map(|(o, l2, inner)| (*o, *l2, retier(inner, to)))
                .collect(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;

    #[test]
    fn heat_decays() {
        let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
        let store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        hsm.observe(
            &[FdmiRecord::ObjectWritten {
                obj: ObjectId(1),
                offset: 0,
                len: 1 << 20,
                at: 0.0,
            }],
            &store,
        );
        let hot = hsm.score(ObjectId(1), 1.0);
        let cooled = hsm.score(ObjectId(1), 600.0);
        assert!(hot > 1.0);
        assert!(cooled < 0.01 * hot);
    }

    #[test]
    fn hot_objects_promote_cold_demote() {
        let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
        let store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        // hot object: many touches
        for i in 0..10 {
            hsm.observe(
                &[FdmiRecord::ObjectRead {
                    obj: ObjectId(1),
                    offset: 0,
                    len: 4096,
                    at: i as f64,
                }],
                &store,
            );
        }
        // cold object: one old touch
        hsm.observe(
            &[FdmiRecord::ObjectRead {
                obj: ObjectId(2),
                offset: 0,
                len: 4096,
                at: 0.0,
            }],
            &store,
        );
        let plan = hsm.plan(500.0);
        let promoted: Vec<_> =
            plan.iter().filter(|m| m.to.tier() < m.from.tier()).collect();
        let demoted: Vec<_> =
            plan.iter().filter(|m| m.to.tier() > m.from.tier()).collect();
        // at t=500 the hot object has cooled too; re-plan right after use
        let plan_hot = hsm.plan(10.0);
        assert!(
            plan_hot.iter().any(|m| m.obj == ObjectId(1)
                && m.to.tier() < m.from.tier()),
            "hot object should promote: {plan_hot:?}"
        );
        assert!(
            demoted.iter().any(|m| m.obj == ObjectId(2)),
            "cold object should demote: {plan:?} {promoted:?}"
        );
    }

    #[test]
    fn static_policy_never_moves() {
        let mut hsm = Hsm::new(TieringPolicy::Static);
        let store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        for i in 0..20 {
            hsm.observe(
                &[FdmiRecord::ObjectRead {
                    obj: ObjectId(1),
                    offset: 0,
                    len: 1 << 20,
                    at: i as f64,
                }],
                &store,
            );
        }
        assert!(hsm.plan(21.0).is_empty());
    }

    #[test]
    fn migration_preserves_bytes_and_changes_tier() {
        let mut store = MeroStore::new(Testbed::sage_prototype().build_cluster());
        let obj = store
            .create_object(4096, Layout::default())
            .unwrap();
        let data: Vec<u8> = (0..4 * 65536u32).map(|i| (i % 251) as u8).collect();
        store.write_object(obj, 0, &data, 0.0, None).unwrap();
        let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
        let plan = vec![Migration {
            obj,
            from: DeviceKind::Ssd,
            to: DeviceKind::Nvram,
        }];
        let t = hsm.migrate(&mut store, &plan, 1.0).unwrap();
        assert!(t > 1.0);
        assert_eq!(store.object(obj).unwrap().layout.tier(), DeviceKind::Nvram);
        let (back, _) = store.read_object(obj, 0, data.len() as u64, t).unwrap();
        assert_eq!(back, data, "migration must not lose bytes");
        assert_eq!(hsm.migrations_run, 1);
    }

    #[test]
    fn tier_ladder_is_consistent() {
        // promote then demote returns to the same tier (where defined)
        for t in [DeviceKind::Ssd, DeviceKind::Hdd] {
            let up = promote_target(t).unwrap();
            assert_eq!(demote_target(up), Some(t));
        }
        assert_eq!(promote_target(DeviceKind::Nvram), None);
        assert_eq!(demote_target(DeviceKind::Smr), None);
    }
}
