//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the offline
//! build carries no proc-macro dependencies. See ARCHITECTURE.md
//! §Module map.

use std::fmt;

/// Errors surfaced by the SAGE stack.
#[derive(Debug)]
pub enum SageError {
    /// Object / index / container identifier not found.
    NotFound(String),

    /// An operation violated API preconditions (bad offset, size, state).
    Invalid(String),

    /// Storage pool exhausted or device over capacity.
    NoSpace(String),

    /// Too many failed devices in a parity group to reconstruct data.
    Unavailable(String),

    /// Transaction aborted (conflict, explicit abort, or failed node).
    TxAborted(String),

    /// Error from the PJRT runtime (artifact load / compile / execute).
    Runtime(String),

    /// Config file / CLI parse errors.
    Config(String),

    /// On-disk / in-flight data failed an integrity check.
    Integrity(String),

    /// Recovery-plane bookkeeping went inconsistent mid-pass (overlap
    /// table / outcome index). Surfaced as a typed value — the
    /// recovery plane never panics (`no-panic-in-recovery`); the
    /// failure-feed consumer converts this into a
    /// [`RecoveryVerdict::Failed`](crate::clovis::RecoveryVerdict)
    /// outcome so the event stays accounted.
    Recovery(String),

    /// Underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for SageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SageError::NotFound(s) => write!(f, "no such entity: {s}"),
            SageError::Invalid(s) => write!(f, "invalid argument: {s}"),
            SageError::NoSpace(s) => write!(f, "out of space: {s}"),
            SageError::Unavailable(s) => write!(f, "data unavailable: {s}"),
            SageError::TxAborted(s) => write!(f, "transaction aborted: {s}"),
            SageError::Runtime(s) => write!(f, "runtime error: {s}"),
            SageError::Config(s) => write!(f, "config error: {s}"),
            SageError::Integrity(s) => write!(f, "integrity violation: {s}"),
            SageError::Recovery(s) => {
                write!(f, "recovery-plane bookkeeping error: {s}")
            }
            SageError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for SageError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SageError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SageError {
    fn from(e: std::io::Error) -> Self {
        SageError::Io(e)
    }
}

/// Convenient result alias used across the crate.
pub type Result<T> = std::result::Result<T, SageError>;

#[cfg(feature = "pjrt")]
impl From<xla::Error> for SageError {
    fn from(e: xla::Error) -> Self {
        SageError::Runtime(e.to_string())
    }
}
