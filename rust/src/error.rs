//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the SAGE stack.
#[derive(Error, Debug)]
pub enum SageError {
    /// Object / index / container identifier not found.
    #[error("no such entity: {0}")]
    NotFound(String),

    /// An operation violated API preconditions (bad offset, size, state).
    #[error("invalid argument: {0}")]
    Invalid(String),

    /// Storage pool exhausted or device over capacity.
    #[error("out of space: {0}")]
    NoSpace(String),

    /// Too many failed devices in a parity group to reconstruct data.
    #[error("data unavailable: {0}")]
    Unavailable(String),

    /// Transaction aborted (conflict, explicit abort, or failed node).
    #[error("transaction aborted: {0}")]
    TxAborted(String),

    /// Error from the PJRT runtime (artifact load / compile / execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Config file / CLI parse errors.
    #[error("config error: {0}")]
    Config(String),

    /// On-disk / in-flight data failed an integrity check.
    #[error("integrity violation: {0}")]
    Integrity(String),

    /// Underlying I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Convenient result alias used across the crate.
pub type Result<T> = std::result::Result<T, SageError>;

impl From<xla::Error> for SageError {
    fn from(e: xla::Error) -> Self {
        SageError::Runtime(e.to_string())
    }
}
