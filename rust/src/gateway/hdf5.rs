//! HDF5-style hierarchical data layer (§3.2.4).
//!
//! "The HDF5 data format needs to be supported in SAGE, and is layered
//! directly on top of Clovis. The HDF5 will use the Virtual Object
//! Layer Infrastructure … to interface with Clovis."
//!
//! A faithful-in-spirit VOL mapping: groups form a hierarchy in the
//! KVS; datasets are typed n-dimensional arrays whose raw data lives in
//! a Mero object (row-major, element-wise little-endian); attributes
//! are small KV records. Hyperslab reads/writes translate to
//! block-aligned object I/O, executed through the Clovis session API
//! (ISSUE 4): envelope reads and persist-by-move writes are session
//! ops on the sharded per-device scheduler.

use crate::clovis::{Client, Extent};
use crate::error::{Result, SageError};
use crate::mero::{IndexId, Layout, ObjectId};

/// Supported element types.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F64,
    I32,
    I64,
}

impl Dtype {
    /// Bytes per element.
    pub fn size(self) -> u64 {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F64 | Dtype::I64 => 8,
        }
    }

    fn tag(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F64 => 1,
            Dtype::I32 => 2,
            Dtype::I64 => 3,
        }
    }

    fn from_tag(t: u8) -> Option<Dtype> {
        Some(match t {
            0 => Dtype::F32,
            1 => Dtype::F64,
            2 => Dtype::I32,
            3 => Dtype::I64,
            _ => return None,
        })
    }
}

/// Dataset metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetInfo {
    pub obj: ObjectId,
    pub dtype: Dtype,
    pub shape: Vec<u64>,
}

impl DatasetInfo {
    /// Total elements.
    pub fn len(&self) -> u64 {
        self.shape.iter().product()
    }

    /// True when any dimension is zero.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn encode(&self) -> Vec<u8> {
        let mut v = vec![b'S', self.dtype.tag(), self.shape.len() as u8];
        v.extend_from_slice(&self.obj.0.to_be_bytes());
        for d in &self.shape {
            v.extend_from_slice(&d.to_be_bytes());
        }
        v
    }

    fn decode(raw: &[u8]) -> Option<DatasetInfo> {
        if raw.len() < 11 || raw[0] != b'S' {
            return None;
        }
        let dtype = Dtype::from_tag(raw[1])?;
        let ndim = raw[2] as usize;
        let obj = ObjectId(u64::from_be_bytes(raw[3..11].try_into().ok()?));
        let mut shape = Vec::with_capacity(ndim);
        for i in 0..ndim {
            let s = 11 + i * 8;
            shape.push(u64::from_be_bytes(raw.get(s..s + 8)?.try_into().ok()?));
        }
        Some(DatasetInfo { obj, dtype, shape })
    }
}

/// The HDF5-like file: one namespace index + dataset objects.
pub struct H5File {
    idx: IndexId,
}

impl H5File {
    /// Create/open a fresh file.
    pub fn create(client: &mut Client) -> H5File {
        let idx = client.create_index();
        let f = H5File { idx };
        let _ = client
            .store
            .index_mut(idx)
            .map(|i| i.put(b"/".to_vec(), b"G".to_vec()));
        f
    }

    /// Create a group (parents must exist; "/" exists).
    pub fn create_group(&self, client: &mut Client, path: &str) -> Result<()> {
        let parent = parent_of(path);
        if !self.is_group(client, &parent)? {
            return Err(SageError::NotFound(format!("group {parent}")));
        }
        client
            .store
            .index_mut(self.idx)?
            .put(path.as_bytes().to_vec(), b"G".to_vec());
        Ok(())
    }

    fn is_group(&self, client: &Client, path: &str) -> Result<bool> {
        Ok(client.store.index(self.idx)?.get(path.as_bytes()) == Some(b"G".as_ref()))
    }

    /// Create a dataset of `shape` × `dtype` under `path`.
    pub fn create_dataset(
        &self,
        client: &mut Client,
        path: &str,
        dtype: Dtype,
        shape: &[u64],
    ) -> Result<DatasetInfo> {
        let parent = parent_of(path);
        if !self.is_group(client, &parent)? {
            return Err(SageError::NotFound(format!("group {parent}")));
        }
        let obj = client.create_object_with(4096, Layout::default())?;
        let info = DatasetInfo { obj, dtype, shape: shape.to_vec() };
        client
            .store
            .index_mut(self.idx)?
            .put(path.as_bytes().to_vec(), info.encode());
        Ok(info)
    }

    /// Dataset metadata.
    pub fn dataset(&self, client: &Client, path: &str) -> Result<DatasetInfo> {
        client
            .store
            .index(self.idx)?
            .get(path.as_bytes())
            .and_then(DatasetInfo::decode)
            .ok_or_else(|| SageError::NotFound(format!("dataset {path}")))
    }

    /// Write a contiguous element range `[start, start+n)` (row-major
    /// flat index) of f32 data.
    pub fn write_f32(
        &self,
        client: &mut Client,
        path: &str,
        start: u64,
        data: &[f32],
    ) -> Result<()> {
        let info = self.dataset(client, path)?;
        if info.dtype != Dtype::F32 {
            return Err(SageError::Invalid("dtype mismatch".into()));
        }
        if start + data.len() as u64 > info.len() {
            return Err(SageError::Invalid("write past dataset extent".into()));
        }
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        write_bytes(client, info.obj, start * 4, &bytes)
    }

    /// Read `[start, start+n)` f32 elements.
    pub fn read_f32(
        &self,
        client: &mut Client,
        path: &str,
        start: u64,
        n: u64,
    ) -> Result<Vec<f32>> {
        let info = self.dataset(client, path)?;
        if info.dtype != Dtype::F32 {
            return Err(SageError::Invalid("dtype mismatch".into()));
        }
        if start + n > info.len() {
            return Err(SageError::Invalid("read past dataset extent".into()));
        }
        let bytes = read_bytes(client, info.obj, start * 4, n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Set a string attribute on any path.
    pub fn set_attr(
        &self,
        client: &mut Client,
        path: &str,
        name: &str,
        value: &str,
    ) -> Result<()> {
        client.store.index_mut(self.idx)?.put(
            format!("{path}\x01{name}").into_bytes(),
            value.as_bytes().to_vec(),
        );
        Ok(())
    }

    /// Get a string attribute.
    pub fn attr(&self, client: &Client, path: &str, name: &str) -> Result<String> {
        client
            .store
            .index(self.idx)?
            .get(format!("{path}\x01{name}").as_bytes())
            .map(|v| String::from_utf8_lossy(v).to_string())
            .ok_or_else(|| SageError::NotFound(format!("attr {path}@{name}")))
    }

    /// List direct children of a group (datasets and groups).
    pub fn list(&self, client: &Client, group: &str) -> Result<Vec<String>> {
        let prefix = if group == "/" { "/".to_string() } else { format!("{group}/") };
        let mut out = Vec::new();
        for (k, _) in client.store.index(self.idx)?.scan(prefix.as_bytes(), usize::MAX) {
            let key = String::from_utf8_lossy(&k).to_string();
            if !key.starts_with(&prefix) {
                break;
            }
            if key.contains('\x01') {
                continue; // attribute records
            }
            let rest = &key[prefix.len()..];
            if !rest.is_empty() && !rest.contains('/') {
                out.push(rest.to_string());
            }
        }
        Ok(out)
    }
}

fn parent_of(path: &str) -> String {
    match path.rfind('/') {
        Some(0) | None => "/".to_string(),
        Some(i) => path[..i].to_string(),
    }
}

/// Byte-granular object write via aligned RMW (shared with POSIX
/// view), executed through the Clovis session API: the envelope read
/// is one session read op (`readv`), the patched envelope persists by
/// move through one session write op (`writev_owned` — no payload
/// copy into block storage).
fn write_bytes(client: &mut Client, obj: ObjectId, offset: u64, data: &[u8]) -> Result<()> {
    const BS: u64 = 4096;
    let start = offset / BS * BS;
    let end = (offset + data.len() as u64).div_ceil(BS) * BS;
    let mut buf = read_bytes(client, obj, start, end - start)?;
    let o = (offset - start) as usize;
    buf[o..o + data.len()].copy_from_slice(data);
    client.writev_owned(&obj, vec![(start, buf)])?;
    Ok(())
}

fn read_bytes(client: &mut Client, obj: ObjectId, offset: u64, len: u64) -> Result<Vec<u8>> {
    const BS: u64 = 4096;
    let start = offset / BS * BS;
    let end = (offset + len).div_ceil(BS) * BS;
    let mut buf = client
        .readv(&obj, &[Extent::new(start, end - start)])?
        .swap_remove(0);
    let o = (offset - start) as usize;
    buf.drain(..o);
    buf.truncate(len as usize);
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;

    fn setup() -> (Client, H5File) {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let f = H5File::create(&mut c);
        (c, f)
    }

    #[test]
    fn dataset_roundtrip_2d() {
        let (mut c, f) = setup();
        f.create_group(&mut c, "/fields").unwrap();
        let info = f
            .create_dataset(&mut c, "/fields/Ex", Dtype::F32, &[64, 128])
            .unwrap();
        assert_eq!(info.len(), 8192);
        let data: Vec<f32> = (0..8192).map(|i| i as f32 * 0.5).collect();
        f.write_f32(&mut c, "/fields/Ex", 0, &data).unwrap();
        let back = f.read_f32(&mut c, "/fields/Ex", 0, 8192).unwrap();
        assert_eq!(back, data);
        // hyperslab: one row
        let row = f.read_f32(&mut c, "/fields/Ex", 128 * 3, 128).unwrap();
        assert_eq!(row, &data[128 * 3..128 * 4]);
    }

    #[test]
    fn attributes_and_listing() {
        let (mut c, f) = setup();
        f.create_group(&mut c, "/run").unwrap();
        f.create_dataset(&mut c, "/run/particles", Dtype::F32, &[100, 8])
            .unwrap();
        f.set_attr(&mut c, "/run", "code", "mini-iPIC3D").unwrap();
        f.set_attr(&mut c, "/run/particles", "units", "normalized").unwrap();
        assert_eq!(f.attr(&c, "/run", "code").unwrap(), "mini-iPIC3D");
        assert_eq!(f.list(&c, "/").unwrap(), vec!["run"]);
        assert_eq!(f.list(&c, "/run").unwrap(), vec!["particles"]);
    }

    #[test]
    fn bounds_and_dtype_enforced() {
        let (mut c, f) = setup();
        f.create_dataset(&mut c, "/d", Dtype::F32, &[10]).unwrap();
        assert!(f.write_f32(&mut c, "/d", 8, &[1.0, 2.0, 3.0]).is_err());
        assert!(f.read_f32(&mut c, "/d", 0, 11).is_err());
        f.create_dataset(&mut c, "/i", Dtype::I64, &[10]).unwrap();
        assert!(f.write_f32(&mut c, "/i", 0, &[1.0]).is_err());
        assert!(f.create_dataset(&mut c, "/nogroup/x", Dtype::F32, &[1]).is_err());
    }

    #[test]
    fn partial_writes_preserve_rest() {
        let (mut c, f) = setup();
        f.create_dataset(&mut c, "/d", Dtype::F32, &[4096]).unwrap();
        let ones = vec![1.0f32; 4096];
        f.write_f32(&mut c, "/d", 0, &ones).unwrap();
        f.write_f32(&mut c, "/d", 1000, &[9.0, 9.0]).unwrap();
        let back = f.read_f32(&mut c, "/d", 998, 6).unwrap();
        assert_eq!(back, vec![1.0, 1.0, 9.0, 9.0, 1.0, 1.0]);
    }
}
