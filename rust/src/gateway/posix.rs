//! pNFS/POSIX gateway (§3.2.3 "Parallel File System Access").
//!
//! "Many of the SAGE use cases will need the support of POSIX compliant
//! storage access. This access is provided through the pNFS gateway
//! built on top of Clovis. However, pNFS will need some POSIX semantics
//! (to abstract namespaces on top of Mero objects) to be developed by
//! leveraging Mero's KVS."
//!
//! Exactly that: a hierarchical namespace kept in one KV index
//! (`path -> inode record`), files backed by Mero objects, directories
//! as key prefixes. Byte-granular file I/O is translated to
//! block-aligned object I/O here (POSIX's looser alignment is part of
//! what the gateway provides). Vectored calls ride the Clovis session
//! API end to end (ISSUE 4): one session read op for the RMW envelope
//! reads, then ONE cross-kind session carrying both the data write and
//! the namespace (inode) KVS update — every op dispatched to the
//! group's per-device shards (`sim::sched`; see ARCHITECTURE.md
//! §Module map).

use crate::clovis::{Client, Extent};
use crate::error::{Result, SageError};
use crate::mero::{IndexId, Layout, ObjectId};

/// Inode record stored in the namespace index.
#[derive(Debug, Clone, PartialEq)]
pub enum Inode {
    File { obj: ObjectId, size: u64 },
    Dir,
}

impl Inode {
    fn encode(&self) -> Vec<u8> {
        match self {
            Inode::Dir => b"D".to_vec(),
            Inode::File { obj, size } => {
                let mut v = b"F".to_vec();
                v.extend_from_slice(&obj.0.to_be_bytes());
                v.extend_from_slice(&size.to_be_bytes());
                v
            }
        }
    }

    fn decode(raw: &[u8]) -> Option<Inode> {
        match raw.first()? {
            b'D' => Some(Inode::Dir),
            b'F' if raw.len() == 17 => Some(Inode::File {
                obj: ObjectId(u64::from_be_bytes(raw[1..9].try_into().ok()?)),
                size: u64::from_be_bytes(raw[9..17].try_into().ok()?),
            }),
            _ => None,
        }
    }
}

/// The POSIX namespace gateway.
pub struct PosixGateway {
    ns: IndexId,
    block_size: u64,
}

impl PosixGateway {
    /// Mount a fresh namespace on `client` (creates the root).
    pub fn mount(client: &mut Client) -> Result<PosixGateway> {
        let ns = client.create_index();
        let gw = PosixGateway { ns, block_size: 4096 };
        client
            .store
            .index_mut(ns)?
            .put(b"/".to_vec(), Inode::Dir.encode());
        Ok(gw)
    }

    fn norm(path: &str) -> Result<String> {
        if !path.starts_with('/') || path.contains("//") {
            return Err(SageError::Invalid(format!("bad path {path}")));
        }
        Ok(path.trim_end_matches('/').to_string())
    }

    fn parent(path: &str) -> String {
        match path.rfind('/') {
            Some(0) | None => "/".to_string(),
            Some(i) => path[..i].to_string(),
        }
    }

    /// Look up a path.
    pub fn stat(&self, client: &Client, path: &str) -> Result<Inode> {
        let p = Self::norm(path)?;
        let key = if p.is_empty() { "/".to_string() } else { p };
        client
            .store
            .index(self.ns)?
            .get(key.as_bytes())
            .and_then(Inode::decode)
            .ok_or_else(|| SageError::NotFound(format!("path {path}")))
    }

    /// mkdir (parent must exist).
    pub fn mkdir(&self, client: &mut Client, path: &str) -> Result<()> {
        let p = Self::norm(path)?;
        self.stat(client, &Self::parent(&p))?;
        client
            .store
            .index_mut(self.ns)?
            .put(p.into_bytes(), Inode::Dir.encode());
        Ok(())
    }

    /// creat: a new empty file backed by a fresh object.
    pub fn create(&self, client: &mut Client, path: &str) -> Result<ObjectId> {
        let p = Self::norm(path)?;
        match self.stat(client, &Self::parent(&p))? {
            Inode::Dir => {}
            _ => return Err(SageError::Invalid("parent is a file".into())),
        }
        let obj = client.create_object_with(self.block_size, Layout::default())?;
        client
            .store
            .index_mut(self.ns)?
            .put(p.into_bytes(), Inode::File { obj, size: 0 }.encode());
        Ok(obj)
    }

    /// pwrite: byte-granular write, translated to block-aligned object
    /// I/O (read-modify-write of the edge blocks). Single-part
    /// convenience over [`PosixGateway::writev`].
    pub fn write(
        &self,
        client: &mut Client,
        path: &str,
        offset: u64,
        data: &[u8],
    ) -> Result<()> {
        self.writev(client, path, &[(offset, data)])
    }

    /// Vectored pwrite (`pwritev` analog): every part's block-aligned
    /// envelope is read-modified once (overlapping/adjacent envelopes
    /// are merged first, so shared edge blocks are RMW'd exactly once)
    /// and the whole batch goes to storage as ONE Clovis op group
    /// (§Perf: the batched zero-copy write path, sharded across the
    /// envelopes' home devices by the group scheduler — a slow device
    /// only delays the envelopes striped onto it). Parts apply in
    /// order; later parts win where they overlap, matching sequential
    /// pwrites. Zero-length parts are no-ops and do not extend the
    /// file (POSIX `pwrite(fd, buf, 0, off)` semantics).
    pub fn writev(
        &self,
        client: &mut Client,
        path: &str,
        parts: &[(u64, &[u8])],
    ) -> Result<()> {
        let p = Self::norm(path)?;
        let Inode::File { obj, size } = self.stat(client, &p)? else {
            return Err(SageError::Invalid(format!("{path} is a directory")));
        };
        let bs = self.block_size;
        // block-aligned envelope per non-empty part
        let mut ranges: Vec<(u64, u64)> = parts
            .iter()
            .filter(|(_, d)| !d.is_empty())
            .map(|(off, d)| {
                (off / bs * bs, (off + d.len() as u64).div_ceil(bs) * bs)
            })
            .collect();
        if ranges.is_empty() {
            return Ok(());
        }
        ranges.sort_unstable();
        let mut merged: Vec<(u64, u64)> = Vec::with_capacity(ranges.len());
        for (s, e) in ranges {
            match merged.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => merged.push((s, e)),
            }
        }
        // RMW each merged envelope exactly once, reading them all as
        // ONE session read op (`readv` is a one-op session; one
        // ADDB/FDMI record for the batch, adjacent envelopes coalesce
        // into one striped read)
        let read_exts: Vec<Extent> = merged
            .iter()
            .map(|(s, e)| Extent::new(*s, e - s))
            .collect();
        let bufs = client.readv(&obj, &read_exts)?;
        let mut extents: Vec<(u64, Vec<u8>)> = merged
            .iter()
            .zip(bufs)
            .map(|((s, _), buf)| (*s, buf))
            .collect();
        // apply parts in order (each lies inside exactly one envelope)
        let mut new_size = size;
        for (off, data) in parts {
            if data.is_empty() {
                continue;
            }
            let end = off + data.len() as u64;
            new_size = new_size.max(end);
            for (s, buf) in extents.iter_mut() {
                if *off >= *s && end <= *s + buf.len() as u64 {
                    let i = (*off - *s) as usize;
                    buf[i..i + data.len()].copy_from_slice(data);
                    break;
                }
            }
        }
        // one batched, persist-by-move session for the whole call: the
        // data write AND the namespace (inode) update are a cross-kind
        // batch on one scheduler-backed op group (ISSUE 4)
        let mut s = client.session();
        s.write_owned(&obj, extents);
        s.idx_put(
            self.ns,
            vec![(p.into_bytes(), Inode::File { obj, size: new_size }.encode())],
        );
        s.run()?;
        Ok(())
    }

    /// pread: byte-granular read (short reads at EOF, like POSIX).
    pub fn read(
        &self,
        client: &mut Client,
        path: &str,
        offset: u64,
        len: u64,
    ) -> Result<Vec<u8>> {
        let p = Self::norm(path)?;
        let Inode::File { obj, size } = self.stat(client, &p)? else {
            return Err(SageError::Invalid(format!("{path} is a directory")));
        };
        if offset >= size {
            return Ok(Vec::new());
        }
        let len = len.min(size - offset);
        let bs = self.block_size;
        let start = offset / bs * bs;
        let end = (offset + len).div_ceil(bs) * bs;
        // §Perf: fill one buffer in place, then trim the alignment slack
        // — no second allocation + copy for the envelope
        let mut buf = vec![0u8; (end - start) as usize];
        client.read_object_into(&obj, start, &mut buf)?;
        let off_in = (offset - start) as usize;
        buf.drain(..off_in);
        buf.truncate(len as usize);
        Ok(buf)
    }

    /// readdir: immediate children of a directory.
    pub fn readdir(&self, client: &Client, path: &str) -> Result<Vec<String>> {
        let p = Self::norm(path)?;
        match self.stat(client, if p.is_empty() { "/" } else { &p })? {
            Inode::Dir => {}
            _ => return Err(SageError::Invalid(format!("{path} not a dir"))),
        }
        let prefix = if p.is_empty() { "/".to_string() } else { format!("{p}/") };
        let mut out = Vec::new();
        for (k, _) in client
            .store
            .index(self.ns)?
            .scan(prefix.as_bytes(), usize::MAX)
        {
            let key = String::from_utf8_lossy(&k).to_string();
            if !key.starts_with(&prefix) {
                break;
            }
            let rest = &key[prefix.len()..];
            if !rest.is_empty() && !rest.contains('/') {
                out.push(rest.to_string());
            }
        }
        Ok(out)
    }

    /// unlink: remove a file and its backing object.
    pub fn unlink(&self, client: &mut Client, path: &str) -> Result<()> {
        let p = Self::norm(path)?;
        let Inode::File { obj, .. } = self.stat(client, &p)? else {
            return Err(SageError::Invalid(format!("{path} is a directory")));
        };
        client.delete_object(obj)?;
        client.store.index_mut(self.ns)?.del(p.as_bytes());
        Ok(())
    }

    /// File size (stat convenience).
    pub fn size(&self, client: &Client, path: &str) -> Result<u64> {
        match self.stat(client, path)? {
            Inode::File { size, .. } => Ok(size),
            Inode::Dir => Ok(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;

    fn setup() -> (Client, PosixGateway) {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let gw = PosixGateway::mount(&mut c).unwrap();
        (c, gw)
    }

    #[test]
    fn mkdir_create_write_read() {
        let (mut c, gw) = setup();
        gw.mkdir(&mut c, "/data").unwrap();
        gw.create(&mut c, "/data/out.bin").unwrap();
        // unaligned write/read (POSIX semantics the gateway provides)
        gw.write(&mut c, "/data/out.bin", 100, b"hello sage").unwrap();
        let back = gw.read(&mut c, "/data/out.bin", 100, 10).unwrap();
        assert_eq!(back, b"hello sage");
        assert_eq!(gw.size(&c, "/data/out.bin").unwrap(), 110);
        // bytes before the write are zeros
        let zeros = gw.read(&mut c, "/data/out.bin", 0, 4).unwrap();
        assert_eq!(zeros, vec![0; 4]);
    }

    #[test]
    fn cross_block_rmw() {
        let (mut c, gw) = setup();
        gw.create(&mut c, "/f").unwrap();
        let payload: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        gw.write(&mut c, "/f", 3000, &payload).unwrap();
        // overwrite a range crossing block boundaries
        gw.write(&mut c, "/f", 4090, b"XYZXYZXYZ").unwrap();
        let back = gw.read(&mut c, "/f", 4090, 9).unwrap();
        assert_eq!(back, b"XYZXYZXYZ");
        let before = gw.read(&mut c, "/f", 3000, 1090).unwrap();
        assert_eq!(&before[..], &payload[..1090]);
    }

    #[test]
    fn writev_matches_sequential_pwrites() {
        let (mut cb, gb) = setup();
        let (mut cs, gs) = setup();
        gb.create(&mut cb, "/v").unwrap();
        gs.create(&mut cs, "/v").unwrap();
        // scattered parts; the middle two share an edge block and the
        // last two overlap outright (later part must win)
        let a: Vec<u8> = (0..5000u32).map(|i| (i % 251) as u8).collect();
        let parts: Vec<(u64, &[u8])> = vec![
            (100, &a[..1000]),
            (4000, &a[1000..2500]),
            (4096 + 200, &a[2500..3000]),
            (20_000, &a[3000..5000]),
            (20_500, &a[..800]),
        ];
        gb.writev(&mut cb, "/v", &parts).unwrap();
        for (off, data) in &parts {
            gs.write(&mut cs, "/v", *off, data).unwrap();
        }
        assert_eq!(gb.size(&cb, "/v").unwrap(), gs.size(&cs, "/v").unwrap());
        let nb = gb.read(&mut cb, "/v", 0, 30_000).unwrap();
        let ns = gs.read(&mut cs, "/v", 0, 30_000).unwrap();
        assert_eq!(nb, ns, "batched pwritev == sequential pwrites");
    }

    #[test]
    fn writev_through_sharded_scheduler_is_deterministic() {
        // the pwritev batch rides the group scheduler; two identical
        // runs must produce identical bytes AND identical virtual time
        let run = || {
            let (mut c, gw) = setup();
            gw.create(&mut c, "/d").unwrap();
            let a: Vec<u8> = (0..9000u32).map(|i| (i % 249) as u8).collect();
            let parts: Vec<(u64, &[u8])> =
                vec![(50, &a[..4000]), (8000, &a[4000..9000])];
            gw.writev(&mut c, "/d", &parts).unwrap();
            let back = gw.read(&mut c, "/d", 0, 14_000).unwrap();
            (back, c.now.to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn zero_length_write_is_a_posix_noop() {
        let (mut c, gw) = setup();
        gw.create(&mut c, "/z").unwrap();
        gw.write(&mut c, "/z", 0, b"abc").unwrap();
        // pwrite of 0 bytes past EOF must not extend the file
        gw.write(&mut c, "/z", 10_000, &[]).unwrap();
        assert_eq!(gw.size(&c, "/z").unwrap(), 3);
        assert_eq!(gw.read(&mut c, "/z", 0, 100).unwrap(), b"abc");
    }

    #[test]
    fn readdir_lists_immediate_children_only() {
        let (mut c, gw) = setup();
        gw.mkdir(&mut c, "/a").unwrap();
        gw.mkdir(&mut c, "/a/b").unwrap();
        gw.create(&mut c, "/a/x.txt").unwrap();
        gw.create(&mut c, "/a/b/deep.txt").unwrap();
        let mut ls = gw.readdir(&c, "/a").unwrap();
        ls.sort();
        assert_eq!(ls, vec!["b", "x.txt"]);
        let root = gw.readdir(&c, "/").unwrap();
        assert_eq!(root, vec!["a"]);
    }

    #[test]
    fn short_read_at_eof_and_errors() {
        let (mut c, gw) = setup();
        gw.create(&mut c, "/short").unwrap();
        gw.write(&mut c, "/short", 0, b"abc").unwrap();
        assert_eq!(gw.read(&mut c, "/short", 1, 100).unwrap(), b"bc");
        assert!(gw.read(&mut c, "/short", 10, 5).unwrap().is_empty());
        assert!(gw.stat(&c, "/nope").is_err());
        assert!(gw.mkdir(&mut c, "/no/parent").is_err());
        assert!(gw.create(&mut c, "relative").is_err());
    }

    #[test]
    fn unlink_frees_object() {
        let (mut c, gw) = setup();
        let obj = gw.create(&mut c, "/tmpfile").unwrap();
        gw.write(&mut c, "/tmpfile", 0, &vec![1u8; 8192]).unwrap();
        gw.unlink(&mut c, "/tmpfile").unwrap();
        assert!(gw.stat(&c, "/tmpfile").is_err());
        assert!(c.store.object(obj).is_err(), "backing object deleted");
    }
}
