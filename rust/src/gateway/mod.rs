//! Gateway stacks over Clovis (§3.2.2 / §3.2.3): legacy and emerging
//! interfaces layered on the same objects, "much as libRados is the
//! interface upon which the CephFS (POSIX), RadosGW (S3) and RBD
//! interfaces are built".
//!
//! * [`posix`] — the pNFS/POSIX gateway: a hierarchical namespace kept
//!   in Mero's KVS, files backed by objects.
//! * [`s3`] — an S3-style bucket/key *view* over the same objects
//!   (Advanced Views, §3.2.1: different windows into the same raw
//!   objects by metadata manipulation, no copies).
//! * [`hdf5`] — an HDF5-style hierarchical dataset layer (groups,
//!   typed n-dimensional datasets, attributes) — the Virtual Object
//!   Layer mapping of §3.2.4.

pub mod hdf5;
pub mod posix;
pub mod s3;
