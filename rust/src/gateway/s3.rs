//! S3-style view (§3.2.1 "Advanced Views and Schemas").
//!
//! "It is quite desirable to have different windows into the same raw
//! objects based on the applications using it. This is possible by
//! manipulation of metadata associated with objects without copying the
//! raw objects … various views such as S3 view, HDF5 View, POSIX view
//! etc on top of the same set of objects."
//!
//! The S3 view is a metadata overlay: buckets are key prefixes in a KV
//! index, S3 keys map to *existing* Mero objects (possibly the same
//! objects a POSIX path or an HDF5 dataset exposes). PUT/GET of whole
//! values, LIST with prefix, ETags from the object checksum.

use crate::clovis::{Client, Extent};
use crate::error::{Result, SageError};
use crate::mero::{IndexId, ObjectId};

/// The S3 view over a Clovis client.
pub struct S3View {
    idx: IndexId,
}

/// Metadata for one S3 key.
#[derive(Debug, Clone, PartialEq)]
pub struct S3Meta {
    pub obj: ObjectId,
    pub size: u64,
    pub etag: u32,
}

impl S3Meta {
    fn encode(&self) -> Vec<u8> {
        let mut v = self.obj.0.to_be_bytes().to_vec();
        v.extend_from_slice(&self.size.to_be_bytes());
        v.extend_from_slice(&self.etag.to_be_bytes());
        v
    }

    fn decode(raw: &[u8]) -> Option<S3Meta> {
        if raw.len() != 20 {
            return None;
        }
        Some(S3Meta {
            obj: ObjectId(u64::from_be_bytes(raw[0..8].try_into().ok()?)),
            size: u64::from_be_bytes(raw[8..16].try_into().ok()?),
            etag: u32::from_be_bytes(raw[16..20].try_into().ok()?),
        })
    }
}

impl S3View {
    /// Create the view (one KV index holds all buckets).
    pub fn new(client: &mut Client) -> S3View {
        S3View { idx: client.create_index() }
    }

    fn key(bucket: &str, key: &str) -> Vec<u8> {
        format!("{bucket}\x00{key}").into_bytes()
    }

    /// PUT: store `data` as an object and bind it to (bucket, key).
    /// One cross-kind Clovis session (ISSUE 4): the padded value
    /// persists by move as an object write op and the key binding is a
    /// KVS op on the same scheduler-backed group.
    pub fn put_object(
        &self,
        client: &mut Client,
        bucket: &str,
        key: &str,
        data: &[u8],
    ) -> Result<S3Meta> {
        let obj = client.create_object(4096)?;
        // pad to block multiple for the object write; logical size in meta
        let mut padded = data.to_vec();
        padded.resize(data.len().div_ceil(4096) * 4096, 0);
        let meta = S3Meta {
            obj,
            size: data.len() as u64,
            etag: crc32fast::hash(data),
        };
        let mut s = client.session();
        s.write_owned(&obj, vec![(0, padded)]);
        s.idx_put(self.idx, vec![(Self::key(bucket, key), meta.encode())]);
        s.run()?;
        Ok(meta)
    }

    /// Expose an *existing* object under an S3 key — the zero-copy view
    /// operation the paper highlights (no data movement, pure metadata).
    pub fn link_object(
        &self,
        client: &mut Client,
        bucket: &str,
        key: &str,
        obj: ObjectId,
        size: u64,
    ) -> Result<()> {
        let etag = {
            let (data, _) =
                crate::mero::sns::read(&mut client.store, obj, 0, size.div_ceil(4096) * 4096, client.now)?;
            crc32fast::hash(&data[..size as usize])
        };
        client
            .store
            .index_mut(self.idx)?
            .put(Self::key(bucket, key), S3Meta { obj, size, etag }.encode());
        Ok(())
    }

    /// GET: fetch the value bytes (one session read op via `readv`).
    pub fn get_object(
        &self,
        client: &mut Client,
        bucket: &str,
        key: &str,
    ) -> Result<Vec<u8>> {
        let meta = self.head_object(client, bucket, key)?;
        let padded = meta.size.div_ceil(4096) * 4096;
        let mut data = client
            .readv(&meta.obj, &[Extent::new(0, padded)])?
            .swap_remove(0);
        data.truncate(meta.size as usize);
        // integrity: the view re-verifies the ETag
        if crc32fast::hash(&data) != meta.etag {
            return Err(SageError::Integrity(format!(
                "s3://{bucket}/{key}: etag mismatch"
            )));
        }
        Ok(data)
    }

    /// HEAD: metadata only.
    pub fn head_object(
        &self,
        client: &Client,
        bucket: &str,
        key: &str,
    ) -> Result<S3Meta> {
        client
            .store
            .index(self.idx)?
            .get(&Self::key(bucket, key))
            .and_then(S3Meta::decode)
            .ok_or_else(|| {
                SageError::NotFound(format!("s3://{bucket}/{key}"))
            })
    }

    /// LIST: keys in a bucket with a prefix.
    pub fn list(
        &self,
        client: &Client,
        bucket: &str,
        prefix: &str,
    ) -> Result<Vec<String>> {
        let scan_from = Self::key(bucket, prefix);
        let mut out = Vec::new();
        for (k, _) in client.store.index(self.idx)?.scan(&scan_from, usize::MAX) {
            let Some(sep) = k.iter().position(|&b| b == 0) else { continue };
            let (b, rest) = k.split_at(sep);
            if b != bucket.as_bytes() {
                break;
            }
            let key = String::from_utf8_lossy(&rest[1..]).to_string();
            if !key.starts_with(prefix) {
                break;
            }
            out.push(key);
        }
        Ok(out)
    }

    /// DELETE: unbind the key (the object lives on if other views
    /// reference it — deletion of data is the object layer's call).
    pub fn delete_key(&self, client: &mut Client, bucket: &str, key: &str) -> Result<bool> {
        Ok(client.store.index_mut(self.idx)?.del(&Self::key(bucket, key)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;

    fn setup() -> (Client, S3View) {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let v = S3View::new(&mut c);
        (c, v)
    }

    #[test]
    fn put_get_head_roundtrip() {
        let (mut c, v) = setup();
        let data = b"the quick brown fox".to_vec();
        let meta = v.put_object(&mut c, "results", "run1/fox.txt", &data).unwrap();
        assert_eq!(meta.size, 19);
        let back = v.get_object(&mut c, "results", "run1/fox.txt").unwrap();
        assert_eq!(back, data);
        let head = v.head_object(&c, "results", "run1/fox.txt").unwrap();
        assert_eq!(head, meta);
    }

    #[test]
    fn list_with_prefix() {
        let (mut c, v) = setup();
        for k in ["a/1", "a/2", "b/1"] {
            v.put_object(&mut c, "bkt", k, b"x").unwrap();
        }
        v.put_object(&mut c, "other", "a/9", b"x").unwrap();
        assert_eq!(v.list(&c, "bkt", "a/").unwrap(), vec!["a/1", "a/2"]);
        assert_eq!(v.list(&c, "bkt", "").unwrap().len(), 3);
        assert_eq!(v.list(&c, "other", "").unwrap(), vec!["a/9"]);
    }

    #[test]
    fn zero_copy_view_over_existing_object() {
        let (mut c, v) = setup();
        // an object written through the plain Clovis API...
        let obj = c.create_object(4096).unwrap();
        let data = vec![7u8; 8192];
        c.write_object(&obj, 0, &data).unwrap();
        let objects_before = c.store.object_count();
        // ...becomes visible through the S3 view without copying
        v.link_object(&mut c, "views", "raw.bin", obj, 8192).unwrap();
        assert_eq!(c.store.object_count(), objects_before, "no new object");
        let back = v.get_object(&mut c, "views", "raw.bin").unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn etag_detects_tampering() {
        let (mut c, v) = setup();
        let meta = v.put_object(&mut c, "b", "k", b"payload").unwrap();
        // corrupt the backing object under the view
        c.store
            .object_mut(meta.obj)
            .unwrap()
            .corrupt_block(0, 2);
        // the unit payload is what read returns; corrupt that too
        let unit = c
            .store
            .object(meta.obj)
            .unwrap()
            .get_unit(0, 0)
            .map(|u| {
                let mut v = u.to_vec();
                v[2] ^= 0xFF;
                v
            });
        if let Some(u) = unit {
            c.store.object_mut(meta.obj).unwrap().put_unit(0, 0, u);
        }
        assert!(matches!(
            v.get_object(&mut c, "b", "k"),
            Err(SageError::Integrity(_))
        ));
    }

    #[test]
    fn delete_unbinds_but_keeps_object() {
        let (mut c, v) = setup();
        let meta = v.put_object(&mut c, "b", "k", b"data").unwrap();
        assert!(v.delete_key(&mut c, "b", "k").unwrap());
        assert!(v.get_object(&mut c, "b", "k").is_err());
        assert!(c.store.object(meta.obj).is_ok(), "object outlives the view");
    }
}
