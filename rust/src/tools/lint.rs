//! `sage lint` — the in-tree determinism & invariant static-analysis
//! pass (ISSUE 9).
//!
//! The whole verification story of this reproduction rests on
//! *bit-identical deterministic replay*: every `prop_*` suite pins
//! schedules via `to_bits` equality against preserved oracles, and the
//! tiered-storage semantics (paper §3.2) are only trustworthy because
//! the same seed always produces the same virtual timeline. This pass
//! makes the house invariants machine-checked on every commit, the
//! same way the clippy `-D warnings` job made style rules
//! non-negotiable in PR 4.
//!
//! # Design
//!
//! A small hand-rolled Rust **tokenizer** (house style — no `syn`
//! dependency, the same way `util/compress.rs` replaced `flate2`)
//! turns each source file into a stream of identifier / punctuation /
//! literal tokens with line numbers. Rules are token-sequence
//! patterns, so string literals, doc comments, and `#[cfg(test)]`
//! regions can never false-positive. Six rules:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `no-wall-clock` | virtual `SimTime` is the only clock outside `bench/` |
//! | `no-hash-iteration` | no `HashMap`/`HashSet` in sim-visible modules |
//! | `scheduler-discipline` | device I/O only through the `IoScheduler` |
//! | `no-panic-in-recovery` | recovery plane fails via typed verdicts, never panics |
//! | `no-ambient-entropy` | all randomness flows through `sim/rng.rs` |
//! | `oracle-freeze` | preserved oracle files carry pinned checksums |
//!
//! # Suppressions
//!
//! A violation is waived by a directive comment on the violating line
//! or the line directly above it. Directives live ONLY in plain `//`
//! comments (never `///` or `//!` doc text) and the reason is
//! mandatory — `allow(<rule>)` without one is itself a `waiver-syntax`
//! violation. The shape is
//!
//! ```text
//! // sage-lint: allow(<rule>, "<non-empty reason>")
//! ```
//!
//! `oracle-freeze` waivers are file-scoped: placing one anywhere in a
//! preserved oracle file acknowledges an intentional edit.
//!
//! Driven by `sage lint [--json]` (exits nonzero on any violation) and
//! the CI `lint` job; fixtures in `tests/lint_rules.rs` pin one
//! violating and one clean snippet per rule.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::util::json::Json;

// ------------------------------------------------------------ rules

/// Wall-clock reads (`Instant::now` / `SystemTime`) outside `bench/`.
pub const NO_WALL_CLOCK: &str = "no-wall-clock";
/// `HashMap`/`HashSet` named in a sim-visible module.
pub const NO_HASH_ITERATION: &str = "no-hash-iteration";
/// Direct `.io()` / `.io_run()` device calls outside the scheduler.
pub const SCHEDULER_DISCIPLINE: &str = "scheduler-discipline";
/// `panic!` / `unwrap()` / `expect()` in the recovery plane.
pub const NO_PANIC_IN_RECOVERY: &str = "no-panic-in-recovery";
/// `rand::` / `thread_rng` / `getrandom` / `Date` outside `sim/rng.rs`.
pub const NO_AMBIENT_ENTROPY: &str = "no-ambient-entropy";
/// Preserved oracle files must match their pinned checksum.
pub const ORACLE_FREEZE: &str = "oracle-freeze";
/// A malformed `sage-lint:` directive (engine-internal rule; it cannot
/// be suppressed and is not a valid `allow(..)` target).
pub const WAIVER_SYNTAX: &str = "waiver-syntax";

/// How a rule's violations count toward the exit status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Reported but does not fail the run.
    Warn,
    /// Fails `sage lint` (nonzero exit) and the CI `lint` job.
    Deny,
}

impl Severity {
    /// Stable lowercase name used in `--json` output.
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// One rule's registry row: name, severity, and the invariant it
/// protects (rendered into ARCHITECTURE.md §Static invariants).
pub struct RuleInfo {
    pub name: &'static str,
    pub severity: Severity,
    pub invariant: &'static str,
}

/// The rule registry. Every rule ships at `Deny`: the invariants here
/// are exactly the ones the preserved oracles already depend on, so a
/// "warning" tier would only institutionalize drift.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        name: NO_WALL_CLOCK,
        severity: Severity::Deny,
        invariant: "virtual SimTime is the only clock in deterministic \
                    code; wall-clock reads are for bench/ and waived \
                    diag timers only",
    },
    RuleInfo {
        name: NO_HASH_ITERATION,
        severity: Severity::Deny,
        invariant: "HashMap/HashSet iteration order is randomly seeded \
                    per process and may leak into virtual times, \
                    reports, or FDMI/ADDB streams; sim-visible modules \
                    use ordered containers",
    },
    RuleInfo {
        name: SCHEDULER_DISCIPLINE,
        severity: Severity::Deny,
        invariant: "every device I/O goes through the cluster-wide \
                    IoScheduler; direct .io()/.io_run() calls are \
                    reserved to sim/sched.rs and the preserved oracles",
    },
    RuleInfo {
        name: NO_PANIC_IN_RECOVERY,
        severity: Severity::Deny,
        invariant: "the recovery plane reports failure through typed \
                    RecoveryVerdict/SageError values, never by \
                    panicking mid-repair",
    },
    RuleInfo {
        name: NO_AMBIENT_ENTROPY,
        severity: Severity::Deny,
        invariant: "all randomness derives from the seeded sim::rng \
                    streams; ambient entropy breaks replay",
    },
    RuleInfo {
        name: ORACLE_FREEZE,
        severity: Severity::Deny,
        invariant: "preserved differential oracles (sns_baseline, \
                    sns_serial, sched_oracle, qos_static_oracle) \
                    change only with an explicit in-file waiver",
    },
];

/// True if `name` is a rule that a directive may `allow(..)`.
pub fn is_known_rule(name: &str) -> bool {
    RULES.iter().any(|r| r.name == name)
}

fn rule_severity(name: &str) -> Severity {
    RULES
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.severity)
        .unwrap_or(Severity::Deny)
}

/// Files allowed to issue direct device I/O: the scheduler itself,
/// its preserved replay oracle, and the preserved serial-fold SNS
/// oracles (which predate the scheduler and are frozen by rule 6).
const SCHED_ALLOWED: &[&str] = &[
    "sim/sched.rs",
    "sim/sched_oracle.rs",
    "sim/qos_static_oracle.rs",
    "mero/sns_baseline.rs",
    "mero/sns_serial.rs",
];

/// Recovery-plane functions in `clovis/mod.rs` covered by
/// `no-panic-in-recovery` (all of `mero/ha.rs` is covered).
const RECOVERY_FNS: &[&str] =
    &["consume_failure_feed", "consume_event", "expand_pool"];

/// Module prefixes where container iteration order can leak into
/// virtual times, reports, or FDMI/ADDB streams.
const SIM_VISIBLE: &[&str] = &["sim/", "mero/", "clovis/", "hsm/"];

/// Pinned CRC32 (IEEE, `\r`-stripped bytes) of each preserved oracle
/// file. Editing an oracle changes its checksum; the edit must carry
/// an in-file `oracle-freeze` waiver to land.
pub const ORACLE_CHECKSUMS: &[(&str, u32)] = &[
    ("mero/sns_baseline.rs", 0x316c_ad27),
    ("mero/sns_serial.rs", 0x2bb7_df49),
    ("sim/qos_static_oracle.rs", 0xd707_c310),
    ("sim/sched_oracle.rs", 0x6253_d5a6),
];

// ------------------------------------------------------- violations

/// One rule hit, anchored to a file and 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub severity: Severity,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Violation {
    fn new(rule: &'static str, file: &str, line: usize, message: String) -> Self {
        Violation {
            rule,
            severity: rule_severity(rule),
            file: file.to_string(),
            line,
            message,
        }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{} [{}] {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// Aggregate result of a lint run.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// Surviving (unsuppressed) violations, sorted by file/line/rule.
    pub violations: Vec<Violation>,
    /// Directives that actually suppressed a hit (plus honored
    /// oracle-freeze waivers). Unused directives are inert.
    pub waivers_honored: usize,
}

impl LintReport {
    /// Violations that fail the run.
    pub fn deny_count(&self) -> usize {
        self.violations
            .iter()
            .filter(|v| v.severity == Severity::Deny)
            .count()
    }

    /// Human-readable rendering (one violation per line + a summary).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for v in &self.violations {
            let _ = writeln!(s, "{v}");
        }
        let _ = write!(
            s,
            "sage lint: {} file(s) scanned, {} violation(s), {} waiver(s) honored",
            self.files_scanned,
            self.violations.len(),
            self.waivers_honored
        );
        s
    }

    /// Machine-readable rendering for `sage lint --json`.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert(
            "files_scanned".to_string(),
            Json::Num(self.files_scanned as f64),
        );
        o.insert(
            "waivers_honored".to_string(),
            Json::Num(self.waivers_honored as f64),
        );
        o.insert("ok".to_string(), Json::Bool(self.deny_count() == 0));
        let vs = self
            .violations
            .iter()
            .map(|v| {
                let mut m = BTreeMap::new();
                m.insert("rule".to_string(), Json::Str(v.rule.to_string()));
                m.insert(
                    "severity".to_string(),
                    Json::Str(v.severity.as_str().to_string()),
                );
                m.insert("file".to_string(), Json::Str(v.file.clone()));
                m.insert("line".to_string(), Json::Num(v.line as f64));
                m.insert(
                    "message".to_string(),
                    Json::Str(v.message.clone()),
                );
                Json::Obj(m)
            })
            .collect();
        o.insert("violations".to_string(), Json::Arr(vs));
        Json::Obj(o)
    }
}

// --------------------------------------------------------- tokenizer

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TokKind {
    Ident,
    Punct,
    Lit,
}

#[derive(Debug, Clone)]
struct Tok {
    kind: TokKind,
    text: String,
    line: usize,
}

/// If `b[i]` starts a string literal — optional `b`/`r` prefixes, raw
/// hashes, then `"` — return `(index past it, newlines inside)`.
fn scan_string(b: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    let mut raw = false;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) == Some(&'r') {
        raw = true;
        j += 1;
    }
    let mut hashes = 0usize;
    if raw {
        while b.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
    }
    if b.get(j) != Some(&'"') {
        return None;
    }
    j += 1;
    let mut nl = 0usize;
    while j < b.len() {
        let c = b[j];
        if c == '\n' {
            nl += 1;
            j += 1;
            continue;
        }
        if !raw && c == '\\' {
            j += 2;
            continue;
        }
        if c == '"' {
            if raw {
                let mut k = 0;
                while k < hashes && b.get(j + 1 + k) == Some(&'#') {
                    k += 1;
                }
                if k == hashes {
                    return Some((j + 1 + hashes, nl));
                }
                j += 1;
                continue;
            }
            return Some((j + 1, nl));
        }
        j += 1;
    }
    Some((j, nl)) // unterminated — consume to EOF
}

/// At `b[i] == '\''`: distinguish a char literal from a lifetime and
/// return the index past it.
fn scan_char_or_lifetime(b: &[char], i: usize) -> usize {
    let j = i + 1;
    match b.get(j) {
        None => j,
        Some('\\') => {
            // escaped char literal: skip to the closing quote
            let mut k = j + 2;
            while k < b.len() && b[k] != '\'' {
                k += 1;
            }
            (k + 1).min(b.len())
        }
        Some(&c) => {
            if (c.is_alphanumeric() || c == '_')
                && b.get(j + 1) == Some(&'\'')
            {
                j + 2 // 'a'
            } else if c.is_alphabetic() || c == '_' {
                // lifetime: ident chars, no closing quote
                let mut k = j;
                while k < b.len() && (b[k].is_alphanumeric() || b[k] == '_')
                {
                    k += 1;
                }
                k
            } else if b.get(j + 1) == Some(&'\'') {
                j + 2 // '(' , ' ' , …
            } else {
                j
            }
        }
    }
}

/// Tokenize a source file. Returns the token stream plus every plain
/// `//` line comment as `(line, text-after-slashes)` — doc comments
/// (`///`, `//!`) and block comments are never directive carriers.
fn tokenize(src: &str) -> (Vec<Tok>, Vec<(usize, String)>) {
    let b: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '/' && b.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut j = start;
            while j < b.len() && b[j] != '\n' {
                j += 1;
            }
            let text: String = b[start..j].iter().collect();
            if !text.starts_with('/') && !text.starts_with('!') {
                comments.push((line, text));
            }
            i = j;
            continue;
        }
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        if c == '"' || c == 'b' || c == 'r' {
            if let Some((j, nl)) = scan_string(&b, i) {
                toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                });
                line += nl;
                i = j;
                continue;
            }
        }
        if c == 'b' && b.get(i + 1) == Some(&'\'') {
            let j = scan_char_or_lifetime(&b, i + 1);
            toks.push(Tok {
                kind: TokKind::Lit,
                text: String::new(),
                line,
            });
            i = j;
            continue;
        }
        if c == '\'' {
            let j = scan_char_or_lifetime(&b, i);
            toks.push(Tok {
                kind: TokKind::Lit,
                text: String::new(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == '_')
            {
                j += 1;
            }
            // fractional part only when a digit follows the dot, so
            // range expressions (`0..n`) stay two Punct tokens
            if b.get(j) == Some(&'.')
                && b.get(j + 1).is_some_and(|d| d.is_ascii_digit())
            {
                j += 1;
                while j < b.len()
                    && (b[j].is_ascii_alphanumeric() || b[j] == '_')
                {
                    j += 1;
                }
            }
            toks.push(Tok {
                kind: TokKind::Lit,
                text: String::new(),
                line,
            });
            i = j;
            continue;
        }
        // `::` is one token so path rules can match it as a unit
        if c == ':' && b.get(i + 1) == Some(&':') {
            toks.push(Tok {
                kind: TokKind::Punct,
                text: "::".to_string(),
                line,
            });
            i += 2;
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    (toks, comments)
}

/// Token-sequence match: each pattern element must equal the text of
/// an `Ident` or `Punct` token (literals never match).
fn m(toks: &[Tok], i: usize, pat: &[&str]) -> bool {
    if i + pat.len() > toks.len() {
        return false;
    }
    pat.iter().enumerate().all(|(k, p)| {
        let t = &toks[i + k];
        t.kind != TokKind::Lit && t.text == *p
    })
}

/// Mask every token inside a `#[cfg(test)]`-attributed item (test
/// mods and fns). Test code may use wall clocks, hash maps, direct
/// device calls and unwraps freely — determinism rules bind the
/// shipping paths.
fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if m(toks, i, &["#", "[", "cfg", "(", "test", ")", "]"]) {
            // skip any further attributes on the same item
            let mut j = i + 7;
            while m(toks, j, &["#", "["]) {
                let mut depth = 0i32;
                j += 1; // at '['
                while j < toks.len() {
                    if toks[j].kind == TokKind::Punct {
                        if toks[j].text == "[" {
                            depth += 1;
                        } else if toks[j].text == "]" {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                    }
                    j += 1;
                }
            }
            // find the item's opening brace (bail at `;`: no body)
            let mut open = None;
            let mut k = j;
            while k < toks.len() {
                if toks[k].kind == TokKind::Punct {
                    if toks[k].text == "{" {
                        open = Some(k);
                        break;
                    }
                    if toks[k].text == ";" {
                        break;
                    }
                }
                k += 1;
            }
            if let Some(o) = open {
                let mut depth = 0i32;
                let mut e = o;
                while e < toks.len() {
                    if toks[e].kind == TokKind::Punct {
                        if toks[e].text == "{" {
                            depth += 1;
                        } else if toks[e].text == "}" {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                    }
                    e += 1;
                }
                let e = e.min(toks.len() - 1);
                for slot in &mut mask[i..=e] {
                    *slot = true;
                }
                i = e + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// Every `fn` body as `(name-token-index, open-brace, close-brace)`.
/// Used to scope `no-panic-in-recovery` to the recovery functions in
/// `clovis/mod.rs`.
fn fn_ranges(toks: &[Tok]) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::new();
    let mut stack: Vec<(usize, usize, usize)> = Vec::new(); // name, open, depth
    let mut pending: Option<usize> = None;
    let mut depth = 0usize;
    for (i, t) in toks.iter().enumerate() {
        match t.kind {
            TokKind::Ident if t.text == "fn" => {
                if toks.get(i + 1).map(|n| n.kind) == Some(TokKind::Ident) {
                    pending = Some(i + 1);
                }
            }
            TokKind::Punct => match t.text.as_str() {
                "{" => {
                    depth += 1;
                    if let Some(p) = pending.take() {
                        stack.push((p, i, depth));
                    }
                }
                "}" => {
                    if let Some(&(p, o, d)) = stack.last() {
                        if d == depth {
                            out.push((p, o, i));
                            stack.pop();
                        }
                    }
                    depth = depth.saturating_sub(1);
                }
                ";" => {
                    // trait method / fn-pointer position without a body
                    pending = None;
                }
                _ => {}
            },
            _ => {}
        }
    }
    out
}

// ------------------------------------------------------- directives

#[derive(Debug, Clone)]
struct Directive {
    line: usize,
    rule: String,
}

/// Parse one plain `//` comment. `None` when it is not a directive at
/// all; `Some(Err(why))` for malformed directives (a `waiver-syntax`
/// violation); `Some(Ok(..))` for a valid waiver.
fn parse_directive(
    line: usize,
    text: &str,
) -> Option<std::result::Result<Directive, String>> {
    let rest = text.trim().strip_prefix("sage-lint:")?.trim();
    let inner = match rest
        .strip_prefix("allow(")
        .and_then(|r| r.strip_suffix(')'))
    {
        Some(x) => x,
        None => {
            return Some(Err(
                "directive must be `allow(<rule>, \"<reason>\")`".to_string()
            ));
        }
    };
    let (rule, reason) = match inner.split_once(',') {
        Some((r, w)) => (r.trim(), w.trim()),
        None => {
            return Some(Err(
                "waiver reason is mandatory: `allow(<rule>, \"<reason>\")`"
                    .to_string(),
            ));
        }
    };
    if !is_known_rule(rule) {
        return Some(Err(format!("unknown rule `{rule}` in waiver")));
    }
    let quoted = reason.len() >= 2
        && reason.starts_with('"')
        && reason.ends_with('"');
    if !quoted || reason[1..reason.len() - 1].trim().is_empty() {
        return Some(Err(
            "waiver reason must be a non-empty quoted string".to_string()
        ));
    }
    Some(Ok(Directive {
        line,
        rule: rule.to_string(),
    }))
}

// ------------------------------------------------------ rule engine

/// Result of linting one file in isolation (`oracle-freeze` is
/// checked at the tree level by [`run_lint`]).
pub struct FileLint {
    pub violations: Vec<Violation>,
    pub waivers_honored: usize,
    /// The file carries a valid file-scoped `oracle-freeze` waiver.
    pub oracle_waiver: bool,
}

fn collect_hits(
    rel: &str,
    toks: &[Tok],
    mask: &[bool],
    ranges: &[(usize, usize, usize)],
    out: &mut Vec<Violation>,
) {
    let bench = rel.starts_with("bench/") || rel == "bench.rs";
    let sim_visible = SIM_VISIBLE.iter().any(|p| rel.starts_with(p));
    let sched_ok = SCHED_ALLOWED.contains(&rel);
    let entropy_ok = rel == "sim/rng.rs";
    let in_recovery = |idx: usize| -> bool {
        if rel == "mero/ha.rs" {
            return true;
        }
        if rel != "clovis/mod.rs" {
            return false;
        }
        ranges.iter().any(|&(n, o, c)| {
            idx > o && idx < c && RECOVERY_FNS.contains(&toks[n].text.as_str())
        })
    };
    for (i, t) in toks.iter().enumerate() {
        if mask[i] || t.kind == TokKind::Lit {
            continue;
        }
        // (1) no-wall-clock
        if !bench
            && (m(toks, i, &["Instant", "::", "now"])
                || (t.kind == TokKind::Ident && t.text == "SystemTime"))
        {
            out.push(Violation::new(
                NO_WALL_CLOCK,
                rel,
                t.line,
                "wall-clock read in deterministic code; virtual SimTime \
                 is the only clock (waiver required for diag timers)"
                    .to_string(),
            ));
        }
        // (2) no-hash-iteration
        if sim_visible
            && t.kind == TokKind::Ident
            && (t.text == "HashMap" || t.text == "HashSet")
        {
            out.push(Violation::new(
                NO_HASH_ITERATION,
                rel,
                t.line,
                format!(
                    "`{}` in a sim-visible module: iteration order is \
                     randomly seeded per process; use an ordered \
                     container (BTreeMap/BTreeSet/sorted Vec)",
                    t.text
                ),
            ));
        }
        // (3) scheduler-discipline — anchored on the method name so a
        // waiver sits naturally above the `.io(..)` line of a chain
        if !sched_ok
            && t.kind == TokKind::Punct
            && t.text == "."
            && (m(toks, i, &[".", "io", "("])
                || m(toks, i, &[".", "io_run", "("]))
        {
            out.push(Violation::new(
                SCHEDULER_DISCIPLINE,
                rel,
                toks[i + 1].line,
                format!(
                    "direct device `.{}()` bypasses the cluster-wide \
                     IoScheduler; submit through Sched/Session",
                    toks[i + 1].text
                ),
            ));
        }
        // (4) no-panic-in-recovery
        if in_recovery(i) {
            let hit = if t.kind == TokKind::Ident
                && t.text == "panic"
                && m(toks, i + 1, &["!"])
            {
                Some(("panic!", t.line))
            } else if m(toks, i, &[".", "unwrap", "("])
                || m(toks, i, &[".", "expect", "("])
            {
                Some((
                    if toks[i + 1].text == "unwrap" {
                        "unwrap()"
                    } else {
                        "expect()"
                    },
                    toks[i + 1].line,
                ))
            } else {
                None
            };
            if let Some((what, line)) = hit {
                out.push(Violation::new(
                    NO_PANIC_IN_RECOVERY,
                    rel,
                    line,
                    format!(
                        "`{what}` in the recovery plane; fail through \
                         typed RecoveryVerdict / SageError::Recovery"
                    ),
                ));
            }
        }
        // (5) no-ambient-entropy
        if !entropy_ok
            && t.kind == TokKind::Ident
            && (m(toks, i, &["rand", "::"])
                || t.text == "thread_rng"
                || t.text == "getrandom"
                || t.text == "Date")
        {
            out.push(Violation::new(
                NO_AMBIENT_ENTROPY,
                rel,
                t.line,
                format!(
                    "ambient entropy source `{}`; all randomness must \
                     flow through the seeded sim::rng streams",
                    t.text
                ),
            ));
        }
    }
}

/// Lint a single source file (token rules + directive handling).
/// `rel` is the `/`-separated path relative to the `src` root, which
/// selects per-module rule scoping.
pub fn lint_source(rel: &str, src: &str) -> FileLint {
    let (toks, comments) = tokenize(src);
    let mut violations = Vec::new();
    let mut directives = Vec::new();
    for (line, text) in &comments {
        match parse_directive(*line, text) {
            None => {}
            Some(Err(why)) => violations.push(Violation::new(
                WAIVER_SYNTAX,
                rel,
                *line,
                why,
            )),
            Some(Ok(d)) => directives.push(d),
        }
    }
    let mask = test_mask(&toks);
    let ranges = fn_ranges(&toks);
    let mut hits = Vec::new();
    collect_hits(rel, &toks, &mask, &ranges, &mut hits);
    // suppression: a matching directive on the violating line (trailing
    // comment) or the line directly above it
    let mut used = vec![false; directives.len()];
    for h in hits {
        let supp = directives.iter().position(|d| {
            d.rule == h.rule && (d.line == h.line || d.line + 1 == h.line)
        });
        match supp {
            Some(k) => used[k] = true,
            None => violations.push(h),
        }
    }
    let oracle_waiver =
        directives.iter().any(|d| d.rule == ORACLE_FREEZE);
    let waivers_honored = used.iter().filter(|u| **u).count();
    violations.sort_by_key(|v| (v.line, v.rule));
    FileLint {
        violations,
        waivers_honored,
        oracle_waiver,
    }
}

// --------------------------------------------------------- tree walk

fn walk(root: &Path, dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(root, &p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            let rel = p.strip_prefix(root).unwrap_or(&p).to_path_buf();
            out.push(rel);
        }
    }
    Ok(())
}

/// Locate the crate `src/` root from wherever `sage` was invoked:
/// repo top level (`rust/src`), inside `rust/` (`src`), else the
/// compile-time manifest dir.
pub fn default_src_root() -> PathBuf {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.join("lib.rs").is_file() {
            return p;
        }
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
}

/// Lint every `.rs` file under `src_root` (sorted walk, so output
/// order is stable) and apply the tree-level `oracle-freeze` checks.
pub fn run_lint(src_root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    walk(src_root, src_root, &mut files)?;
    files.sort();
    let mut report = LintReport::default();
    let mut oracle_seen: BTreeMap<&'static str, (bool, u32)> =
        BTreeMap::new();
    for rel in &files {
        let src = fs::read_to_string(src_root.join(rel))?;
        let rel_s = rel.to_string_lossy().replace('\\', "/");
        let fl = lint_source(&rel_s, &src);
        report.files_scanned += 1;
        report.waivers_honored += fl.waivers_honored;
        report.violations.extend(fl.violations);
        if let Some(&(path, _)) =
            ORACLE_CHECKSUMS.iter().find(|(p, _)| *p == rel_s)
        {
            let norm: Vec<u8> =
                src.bytes().filter(|&b| b != b'\r').collect();
            let mut h = crc32fast::Hasher::new();
            h.update(&norm);
            oracle_seen.insert(path, (fl.oracle_waiver, h.finalize()));
        }
    }
    for &(path, want) in ORACLE_CHECKSUMS {
        match oracle_seen.get(path) {
            None => report.violations.push(Violation::new(
                ORACLE_FREEZE,
                path,
                1,
                "preserved oracle file is missing from the tree"
                    .to_string(),
            )),
            Some(&(waiver, got)) if got != want => {
                if waiver {
                    report.waivers_honored += 1;
                } else {
                    report.violations.push(Violation::new(
                        ORACLE_FREEZE,
                        path,
                        1,
                        format!(
                            "preserved oracle edited (crc32 {got:#010x}, \
                             pinned {want:#010x}); add an in-file \
                             oracle-freeze waiver if intentional"
                        ),
                    ));
                }
            }
            Some(_) => {}
        }
    }
    report.violations.sort_by(|a, b| {
        a.file
            .cmp(&b.file)
            .then(a.line.cmp(&b.line))
            .then(a.rule.cmp(&b.rule))
    });
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        tokenize(src)
            .0
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn tokenizer_skips_strings_and_comments() {
        let src = concat!(
            "// HashMap in a comment\n",
            "/* Instant::now() /* nested */ */\n",
            "let s = \"HashMap thread_rng\";\n",
            "let r = r#\"SystemTime \"quoted\" \"#;\n",
            "let c = 'x'; let l: &'static str = s;\n",
        );
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()));
        assert!(!ids.contains(&"Instant".to_string()));
        assert!(!ids.contains(&"SystemTime".to_string()));
        // the lifetime in `&'static str` is one literal token, not an
        // ident — but the type name after it tokenizes normally
        assert!(ids.contains(&"str".to_string()));
        assert!(ids.contains(&"let".to_string()));
    }

    #[test]
    fn tokenizer_line_numbers_survive_multiline_strings() {
        let src = "let a = \"x\ny\nz\";\nlet b = 1;\n";
        let (toks, _) = tokenize(src);
        let b_tok = toks
            .iter()
            .find(|t| t.kind == TokKind::Ident && t.text == "b")
            .expect("ident b");
        assert_eq!(b_tok.line, 4);
    }

    #[test]
    fn directive_roundtrip_and_rejects() {
        let ok = parse_directive(
            3,
            " sage-lint: allow(no-wall-clock, \"diag timer\")",
        );
        match ok {
            Some(Ok(d)) => {
                assert_eq!(d.line, 3);
                assert_eq!(d.rule, NO_WALL_CLOCK);
            }
            other => {
                let dbg = format!("{other:?}");
                unreachable!("expected valid directive, got {dbg}");
            }
        }
        // not a directive at all
        assert!(parse_directive(1, " plain comment").is_none());
        // missing reason
        assert!(matches!(
            parse_directive(1, "sage-lint: allow(no-wall-clock)"),
            Some(Err(_))
        ));
        // empty reason
        assert!(matches!(
            parse_directive(1, "sage-lint: allow(no-wall-clock, \"  \")"),
            Some(Err(_))
        ));
        // unknown rule
        assert!(matches!(
            parse_directive(1, "sage-lint: allow(no-such-rule, \"x\")"),
            Some(Err(_))
        ));
    }

    #[test]
    fn test_mask_covers_cfg_test_mod() {
        let src = concat!(
            "fn live() { let x = 1; }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn helper() { let h = 2; }\n",
            "}\n",
            "fn live2() { let y = 3; }\n",
        );
        let (toks, _) = tokenize(src);
        let mask = test_mask(&toks);
        let masked_idents: Vec<&str> = toks
            .iter()
            .zip(&mask)
            .filter(|(t, m)| **m && t.kind == TokKind::Ident)
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert!(masked_idents.contains(&"helper"));
        assert!(!masked_idents.contains(&"live"));
        assert!(!masked_idents.contains(&"live2"));
    }

    #[test]
    fn fn_ranges_track_nesting() {
        let src = concat!(
            "fn outer() {\n",
            "    let c = |x: u32| { x + 1 };\n",
            "    inner_call();\n",
            "}\n",
            "fn second() { }\n",
        );
        let (toks, _) = tokenize(src);
        let ranges = fn_ranges(&toks);
        let names: Vec<&str> =
            ranges.iter().map(|&(n, _, _)| toks[n].text.as_str()).collect();
        assert!(names.contains(&"outer"));
        assert!(names.contains(&"second"));
    }

    #[test]
    fn double_colon_is_one_token() {
        let (toks, _) = tokenize("let t = Instant::now();\nfor i in 0..n {}\n");
        let i = toks
            .iter()
            .position(|t| t.text == "Instant")
            .expect("Instant ident");
        assert!(m(&toks, i, &["Instant", "::", "now"]));
        // `..` stays two single-dot puncts (ranges are not paths)
        assert!(toks.iter().filter(|t| t.text == ".").count() >= 2);
        assert_eq!(toks.iter().filter(|t| t.text == "::").count(), 1);
    }

    #[test]
    fn report_json_shape() {
        let rep = LintReport {
            files_scanned: 2,
            violations: vec![Violation::new(
                NO_WALL_CLOCK,
                "sim/x.rs",
                7,
                "msg".to_string(),
            )],
            waivers_honored: 0,
        };
        let j = rep.to_json();
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            j.get("files_scanned").and_then(|v| v.as_u64()),
            Some(2)
        );
        let v = &j.get("violations").expect("violations").items()[0];
        assert_eq!(
            v.get("rule").and_then(|r| r.as_str()),
            Some(NO_WALL_CLOCK)
        );
        assert_eq!(v.get("line").and_then(|l| l.as_u64()), Some(7));
    }
}
