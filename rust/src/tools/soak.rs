//! Long-horizon failure-storm soak harness (ISSUE 6 tentpole): hours
//! of virtual time, thousands of objects, continuous rewrite/read
//! traffic, correlated failure storms, and elastic pool membership —
//! with the durability invariants checked IN the harness, every pass:
//!
//! * **no byte lost within pool tolerance** — every surviving object
//!   reads back bit-exact against its regenerated payload, and a
//!   [`RecoveryVerdict::DataLoss`] may only ever appear when the
//!   concurrent hard-failure set actually exceeded a tier's parity
//!   tolerance (carry-over of unrepaired devices included);
//! * **bounded repair backlog** — every consumer pass drains the feed
//!   to its clock (no due event left behind) and closes every HA
//!   engagement it opened (`HaSubsystem::repairing` empty);
//! * **every [`RecoveryOutcome`] accounted** — verdict counters are
//!   tallied by an exhaustive match (the compiler enforces the
//!   accounting), and their sum must equal the events consumed.
//!
//! The whole run is a pure function of [`SoakConfig`] — same config,
//! same [`SoakReport`], bit-for-bit (`SoakReport` derives `PartialEq`
//! over its `f64` fields precisely so drivers can assert it). The
//! bench (`benches/soak_storm.rs`) and the CLI (`sage soak`) both
//! drive [`run`]; `SAGE_BENCH_QUICK=1` / `--quick` selects
//! [`SoakConfig::quick`].
//!
//! Traffic shape per tick: a handful of whole-object rewrites (payload
//! regenerated from `(seed, slot, version)` — the harness never stores
//! expected bytes, it re-derives them), one rotating read-verify, then
//! a [`Client::consume_failure_feed`] pass over everything due. At
//! evenly-spaced elastic points the pool GROWS (a fresh device joins a
//! tier via [`Client::expand_pool`] and a Migration-class rebalance
//! pulls load onto it) and an old device of the other tier is drained.
//! Recovered devices are re-armed with fresh exponential failure times
//! injected into the live feed, so storms keep coming for the whole
//! horizon.
//!
//! ## §Perf: hot-loop bookkeeping (ISSUE 8)
//!
//! The per-tick loop recycles its scratch instead of reallocating:
//! the live-index and active-id lists are maintained incrementally
//! (rebuilt only when the lost set grows), the per-tier hard-failure
//! sets and the carried-failed set are cleared and refilled in place,
//! and lost-object length lookups go through a prebuilt id→slot map
//! rather than a linear scan per `DataLoss` verdict. Wall-clock phase
//! timers and allocation counters land in [`SoakDiag`] — which is
//! deliberately EXCLUDED from report identity ([`SoakDiag`]'s
//! `PartialEq` always matches), so the bit-identical double-run
//! asserts keep holding.

use crate::clovis::{Client, RecoveryVerdict};
use crate::cluster::failure::{FailureEvent, FailureKind, FailureSchedule};
use crate::config::Testbed;
use crate::error::Result;
use crate::mero::ha::RepairAction;
use crate::mero::{Layout, ObjectId};
use crate::metrics::Stats;
use crate::sim::clock::SimTime;
use crate::sim::device::{DeviceKind, DeviceProfile};
use crate::sim::rng::SimRng;
use std::collections::{HashMap, HashSet};
use std::time::Instant;

/// RAID shape used for every soak object (per-tier 4+1, XOR parity:
/// tolerance is ONE concurrent loss per tier).
const K: u32 = 4;
const P: u32 = 1;
const UNIT: u64 = 65536;

/// Knobs of one soak run. The report is a pure function of this
/// struct — keep every field deterministic (no wall-clock anywhere).
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Master seed; all RNG streams fork from it.
    pub seed: u64,
    /// Virtual horizon in seconds.
    pub horizon: SimTime,
    /// Object population (split across the SSD and HDD tiers).
    pub n_objects: usize,
    /// Full stripes per object (payload = `stripes * K * UNIT` bytes).
    pub object_stripes: u64,
    /// Driver tick in virtual seconds.
    pub tick: SimTime,
    /// Background per-device MTBF (seconds) for the sampled feed and
    /// for re-arming recovered devices.
    pub mtbf: f64,
    /// Fraction of background events that are transient glitches.
    pub transient_ratio: f64,
    /// Correlated storms over the horizon ("vertical" domains: one
    /// device per tier, so a storm alone stays within parity
    /// tolerance — beyond-parity runs are a scripted bench scenario).
    pub storms: usize,
    /// Seconds a storm takes to knock out its whole domain.
    pub storm_window: SimTime,
    /// Elastic membership points spread over the horizon (each point =
    /// one device added to a tier + one device of the other tier
    /// drained).
    pub elastic_points: usize,
    /// Whole-object rewrites per tick.
    pub rewrites_per_tick: usize,
    /// Full-population byte verification every N ticks (always also
    /// runs at the end of the horizon).
    pub verify_every: u64,
}

impl SoakConfig {
    /// CI smoke shape: ~one virtual hour, dozens of objects — the
    /// same invariants, a few seconds of wall clock.
    pub fn quick(seed: u64) -> SoakConfig {
        SoakConfig {
            seed,
            horizon: 3600.0,
            n_objects: 48,
            object_stripes: 2,
            tick: 60.0,
            mtbf: 1800.0,
            transient_ratio: 0.4,
            storms: 3,
            storm_window: 5.0,
            elastic_points: 2,
            rewrites_per_tick: 4,
            verify_every: 10,
        }
    }

    /// The long-horizon shape: six virtual hours, thousands of
    /// objects, a storm roughly every half hour.
    pub fn full(seed: u64) -> SoakConfig {
        SoakConfig {
            seed,
            horizon: 6.0 * 3600.0,
            n_objects: 2048,
            object_stripes: 1,
            tick: 60.0,
            mtbf: 3600.0,
            transient_ratio: 0.4,
            storms: 12,
            storm_window: 10.0,
            elastic_points: 4,
            rewrites_per_tick: 8,
            verify_every: 30,
        }
    }
}

/// Everything a soak run measured, plus the counters the invariants
/// were checked against. Bit-for-bit reproducible from the config —
/// drivers assert two runs compare equal.
#[derive(Debug, Clone, PartialEq)]
pub struct SoakReport {
    pub ticks: u64,
    pub final_now: SimTime,
    /// Failure events consumed (== the sum of all verdict counters).
    pub events_consumed: u64,
    pub recovered: u64,
    pub transient_retried: u64,
    pub aborted_by_refailure: u64,
    pub escalated_to_repair: u64,
    pub absorbed_by_escalation: u64,
    pub data_loss_events: u64,
    pub failed_recoveries: u64,
    pub no_action: u64,
    /// Objects declared unrecoverable (removed from traffic; their
    /// reads must keep erroring).
    pub objects_lost: u64,
    pub bytes_rebuilt: u64,
    pub bytes_rebalanced: u64,
    pub bytes_drained: u64,
    pub bytes_written: u64,
    pub writes: u64,
    /// Rewrites skipped because a placement device was down (counted,
    /// never silently retried — determinism over throughput).
    pub writes_skipped: u64,
    pub reads_verified: u64,
    pub full_verifies: u64,
    pub devices_added: u64,
    pub drains_run: u64,
    pub drain_errors: u64,
    /// HA counters at the end of the run.
    pub repairs_started: u64,
    pub repairs_aborted: u64,
    /// Largest single consumer pass (outcome count) — the observed
    /// backlog bound.
    pub max_pass_outcomes: u64,
    /// Median / MAD of recovery-session latency (completion − event
    /// time) over every executed recovery.
    pub recovery_latency_p50: f64,
    pub recovery_latency_mad: f64,
    /// Events still pending past the horizon when the run ended.
    pub feed_remaining: u64,
    /// Wall-clock/allocation diagnostics (§Perf, ISSUE 8). NOT part
    /// of report identity: [`SoakDiag`]'s `PartialEq` matches any
    /// value, so the derived `SoakReport` equality still compares
    /// exactly the deterministic fields above.
    pub diag: SoakDiag,
}

/// Wall-clock phase timers + allocation counters for one soak run —
/// the profiling hooks the `ablate_simcore` bench and the nightly
/// soak job read to localize regressions.
///
/// Two runs of one config are bit-identical in every *measured* field
/// of [`SoakReport`] but obviously not in wall clock, so this struct's
/// `PartialEq` deliberately matches ANY other `SoakDiag` — the
/// double-run `assert_eq!(a, b)` determinism pins see through it.
#[derive(Debug, Clone, Default)]
pub struct SoakDiag {
    /// Total wall-clock seconds for the run.
    pub wall_total_s: f64,
    /// Wall seconds in rewrite traffic (payload gen + writes).
    pub wall_traffic_s: f64,
    /// Wall seconds in failure-feed consumer passes (incl. re-arm).
    pub wall_consume_s: f64,
    /// Wall seconds in read-verify + full-population verification.
    pub wall_verify_s: f64,
    /// Heap allocations during the run — 0 unless the driving binary
    /// installed [`CountingAlloc`](crate::util::alloc::CountingAlloc)
    /// as its global allocator (see `tests/alloc_budget.rs`).
    pub allocs: u64,
    /// Bytes requested by those allocations (0 when not counting).
    pub alloc_bytes: u64,
}

impl PartialEq for SoakDiag {
    /// Diagnostics never participate in report identity (see struct
    /// docs): every `SoakDiag` compares equal to every other.
    fn eq(&self, _: &SoakDiag) -> bool {
        true
    }
}

/// One tracked object: payloads are regenerated from
/// `(seed, slot, version)`, never stored by the harness.
struct SoakObject {
    id: ObjectId,
    slot: usize,
    version: u64,
    len: usize,
}

/// Deterministic payload for `(seed, slot, version)`.
fn payload(seed: u64, slot: usize, version: u64, len: usize) -> Vec<u8> {
    let mut rng = SimRng::new(
        seed ^ (slot as u64).wrapping_mul(0x9E3779B97F4A7C15)
            ^ version.wrapping_mul(0xD1B54A32D192ED03),
    );
    let mut d = vec![0u8; len];
    rng.fill_bytes(&mut d);
    d
}

/// Fold one consumer pass into the report: verdict counters (the
/// match is exhaustive — a new variant cannot slip through
/// unreported), rebuilt bytes, recovery latencies, and the lost-object
/// set. Returns how many objects this pass newly declared lost.
fn tally(
    report: &mut SoakReport,
    outcomes: &[crate::clovis::RecoveryOutcome],
    lost: &mut HashSet<ObjectId>,
    latencies: &mut Vec<f64>,
) -> u64 {
    let mut newly_lost = 0u64;
    for out in outcomes {
        report.events_consumed += 1;
        match &out.verdict {
            RecoveryVerdict::NoAction => report.no_action += 1,
            RecoveryVerdict::Recovered => report.recovered += 1,
            RecoveryVerdict::TransientRetried { .. } => {
                report.transient_retried += 1
            }
            RecoveryVerdict::AbortedByRefailure { .. } => {
                report.aborted_by_refailure += 1
            }
            RecoveryVerdict::EscalatedToRepair => {
                report.escalated_to_repair += 1
            }
            RecoveryVerdict::AbsorbedByEscalation => {
                report.absorbed_by_escalation += 1
            }
            RecoveryVerdict::DataLoss { objects: gone } => {
                report.data_loss_events += 1;
                for id in gone {
                    if lost.insert(*id) {
                        newly_lost += 1;
                    }
                }
            }
            RecoveryVerdict::Failed => report.failed_recoveries += 1,
        }
        report.bytes_rebuilt += out.bytes;
        if let Some(t) = out.completed_at {
            latencies.push(t - out.event.at);
        }
    }
    newly_lost
}

/// Median and median-absolute-deviation of a sample.
fn median_mad(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mut s = Stats::new();
    for &x in xs {
        s.push(x);
    }
    let med = s.median();
    let mut dev = Stats::new();
    for &x in xs {
        dev.push((x - med).abs());
    }
    (med, dev.median())
}

/// Run one soak. Invariant violations panic (the harness is the
/// test); recoverable storage errors surface as `Err`.
pub fn run(cfg: &SoakConfig) -> Result<SoakReport> {
    // §Perf profiling hooks: phase timers + allocation counters land
    // in the report's diag (excluded from report identity)
    // sage-lint: allow(no-wall-clock, "diag wall timer: whole-run profiling, outside report identity")
    let t_run = Instant::now();
    let (allocs0, alloc_bytes0) = crate::util::alloc::counts();
    let mut wall_traffic = 0.0f64;
    let mut wall_consume = 0.0f64;
    let mut wall_verify = 0.0f64;

    let mut c = Client::new_sim(Testbed::sage_prototype());
    let mut rng = SimRng::new(cfg.seed);
    let mut traffic_rng = rng.fork(1);
    let mut rearm_rng = rng.fork(2);
    let mut elastic_rng = rng.fork(3);

    // ---- population: objects alternate between the two RAID-capable
    // tiers (NVRAM/SMR enclosures hold only 4 devices — too few for
    // 4+1 — so they sit this harness out)
    let tiers = [DeviceKind::Ssd, DeviceKind::Hdd];
    let len = (cfg.object_stripes * K as u64 * UNIT) as usize;
    let mut objects: Vec<SoakObject> = Vec::with_capacity(cfg.n_objects);
    for slot in 0..cfg.n_objects {
        let tier = tiers[slot % tiers.len()];
        let id = c.create_object_with(
            4096,
            Layout::Raid { data: K, parity: P, unit: UNIT, tier },
        )?;
        c.write_object(&id, 0, &payload(cfg.seed, slot, 0, len))?;
        objects.push(SoakObject { id, slot, version: 0, len });
    }
    let mut bytes_written = (cfg.n_objects * len) as u64;
    let mut writes = cfg.n_objects as u64;

    // ---- the failure feed: background wear + correlated storms over
    // "vertical" domains (one device per tier per storm, so a storm
    // alone never exceeds a tier's parity tolerance)
    let all: Vec<usize> = c
        .store
        .cluster
        .devices_where(|d| matches!(d.profile.kind, DeviceKind::Ssd | DeviceKind::Hdd));
    let ssds = c.store.cluster.devices_where(|d| d.profile.kind == DeviceKind::Ssd);
    let hdds = c.store.cluster.devices_where(|d| d.profile.kind == DeviceKind::Hdd);
    let domains: Vec<Vec<usize>> = (0..cfg.storms.max(1))
        .map(|_| {
            vec![
                ssds[rng.gen_index(ssds.len())],
                hdds[rng.gen_index(hdds.len())],
            ]
        })
        .collect();
    let mut feed = FailureSchedule::sampled_with_storms(
        &all,
        cfg.mtbf,
        cfg.horizon,
        cfg.transient_ratio,
        &domains,
        cfg.storms,
        cfg.storm_window,
        &mut rng,
    );

    // ---- counters
    let mut report = SoakReport {
        ticks: 0,
        final_now: 0.0,
        events_consumed: 0,
        recovered: 0,
        transient_retried: 0,
        aborted_by_refailure: 0,
        escalated_to_repair: 0,
        absorbed_by_escalation: 0,
        data_loss_events: 0,
        failed_recoveries: 0,
        no_action: 0,
        objects_lost: 0,
        bytes_rebuilt: 0,
        bytes_rebalanced: 0,
        bytes_drained: 0,
        bytes_written: 0,
        writes: 0,
        writes_skipped: 0,
        reads_verified: 0,
        full_verifies: 0,
        devices_added: 0,
        drains_run: 0,
        drain_errors: 0,
        repairs_started: 0,
        repairs_aborted: 0,
        max_pass_outcomes: 0,
        recovery_latency_p50: 0.0,
        recovery_latency_mad: 0.0,
        feed_remaining: 0,
        diag: SoakDiag::default(),
    };
    let mut lost: HashSet<ObjectId> = HashSet::new();
    let mut latencies: Vec<f64> = Vec::new();
    // devices still down after a pass (a recovery that could not
    // complete) — they count toward the NEXT pass's concurrency when
    // judging whether a DataLoss verdict was justified
    let mut carried_failed: HashSet<usize> = HashSet::new();
    // §Perf: the live-index and active-id lists are maintained
    // incrementally — rebuilt (in the same filter order) only when
    // the lost set actually grows — and the id→slot map replaces the
    // per-verdict linear object scan. Per-tier hard-failure sets are
    // hoisted out of the loop and cleared in place each tick.
    let mut live: Vec<usize> = (0..objects.len()).collect();
    let mut active: Vec<ObjectId> = objects.iter().map(|o| o.id).collect();
    let slot_of: HashMap<ObjectId, usize> =
        objects.iter().enumerate().map(|(i, o)| (o.id, i)).collect();
    let mut hard_by_tier: [HashSet<usize>; 2] =
        [HashSet::new(), HashSet::new()];
    let elastic_step = cfg.horizon / (cfg.elastic_points + 1) as f64;
    let mut next_elastic = elastic_step;
    let mut elastic_no = 0usize;

    while c.now < cfg.horizon {
        c.now += cfg.tick;
        report.ticks += 1;

        // ---- rewrite traffic: whole-object overwrites with fresh
        // deterministic payloads
        // sage-lint: allow(no-wall-clock, "diag wall timer: rewrite-phase profiling, outside report identity")
        let t_phase = Instant::now();
        for _ in 0..cfg.rewrites_per_tick {
            if live.is_empty() {
                break;
            }
            let i = live[traffic_rng.gen_index(live.len())];
            let o = &mut objects[i];
            // a placement on a carried-over failed device would make a
            // whole-object rewrite partial — skip (counted) instead
            let placeable = c
                .store
                .object(o.id)?
                .placed_units()
                .all(|u| !c.store.cluster.devices[u.device].failed);
            if !placeable {
                report.writes_skipped += 1;
                continue;
            }
            let data = payload(cfg.seed, o.slot, o.version + 1, o.len);
            c.write_object(&o.id, 0, &data)?;
            o.version += 1;
            writes += 1;
            bytes_written += o.len as u64;
        }
        wall_traffic += t_phase.elapsed().as_secs_f64();

        // ---- continuous read verification (one rotating object)
        // sage-lint: allow(no-wall-clock, "diag wall timer: read-verify profiling, outside report identity")
        let t_phase = Instant::now();
        if !live.is_empty() {
            let i = live[(report.ticks as usize) % live.len()];
            let o = &objects[i];
            let got = c.read_object(&o.id, 0, o.len as u64)?;
            assert_eq!(
                got,
                payload(cfg.seed, o.slot, o.version, o.len),
                "soak: surviving object {:?} must read back bit-exact",
                o.id
            );
            report.reads_verified += 1;
        }
        wall_verify += t_phase.elapsed().as_secs_f64();

        // ---- consume everything due; account every outcome
        // sage-lint: allow(no-wall-clock, "diag wall timer: consume-phase profiling, outside report identity")
        let t_phase = Instant::now();
        let outcomes = c.consume_failure_feed(&mut feed, &active);
        report.max_pass_outcomes =
            report.max_pass_outcomes.max(outcomes.len() as u64);
        // tolerance bookkeeping: distinct hard-failed devices per tier
        // this pass, plus devices still down from earlier passes
        for s in &mut hard_by_tier {
            s.clear();
        }
        for d in &carried_failed {
            let kind = c.store.cluster.devices[*d].profile.kind;
            if let Some(t) = tiers.iter().position(|&k| k == kind) {
                hard_by_tier[t].insert(*d);
            }
        }
        for out in &outcomes {
            if let FailureKind::Device(d) = out.event.kind {
                let kind = c.store.cluster.devices[d].profile.kind;
                if let Some(t) = tiers.iter().position(|&k| k == kind) {
                    hard_by_tier[t].insert(d);
                }
            }
        }
        let pass_lost = tally(&mut report, &outcomes, &mut lost, &mut latencies);
        if pass_lost > 0 {
            // the lost set grew: refresh the maintained lists (retain
            // keeps the original order, so the RNG-indexed picks stay
            // bit-identical to a from-scratch filter)
            live.retain(|&i| !lost.contains(&objects[i].id));
            active.retain(|id| !lost.contains(id));
        }
        // invariant: data loss only past parity tolerance — if no tier
        // saw more than P concurrent hard failures, nothing may be lost
        if hard_by_tier.iter().all(|s| s.len() <= P as usize) {
            assert_eq!(
                pass_lost, 0,
                "soak: data loss within parity tolerance (tick {})",
                report.ticks
            );
        }
        // newly-lost objects must surface as errors, never stale bytes
        for out in &outcomes {
            if let RecoveryVerdict::DataLoss { objects: gone } = &out.verdict {
                for id in gone {
                    let len = slot_of
                        .get(id)
                        .map(|&i| objects[i].len as u64)
                        .unwrap_or(1);
                    assert!(
                        c.read_object(id, 0, len).is_err(),
                        "soak: lost object {id:?} must error on read"
                    );
                }
            }
        }
        // invariant: bounded backlog — the pass drained the feed to
        // the clock and closed every engagement it opened
        assert!(
            feed.peek_due(c.now).is_empty(),
            "soak: consumer pass left due events behind (tick {})",
            report.ticks
        );
        assert!(
            c.store.ha.repairing().is_empty(),
            "soak: consumer pass left an HA engagement open (tick {})",
            report.ticks
        );
        carried_failed.clear();
        carried_failed.extend(
            c.store
                .cluster
                .devices
                .iter()
                .enumerate()
                .filter(|(_, d)| d.failed)
                .map(|(i, _)| i),
        );
        // re-arm every recovered device with a fresh exponential
        // failure time so storms keep coming over the long horizon
        for out in &outcomes {
            let d = match (&out.verdict, out.action.clone()) {
                (
                    RecoveryVerdict::Recovered
                    | RecoveryVerdict::EscalatedToRepair,
                    RepairAction::RebuildDevice(d)
                    | RepairAction::ProactiveDrain(d),
                ) => d,
                _ => continue,
            };
            let at = out.completed_at.unwrap_or(c.now)
                + rearm_rng.gen_exp(cfg.mtbf);
            if at < cfg.horizon {
                let kind = if rearm_rng.gen_f64() < cfg.transient_ratio {
                    FailureKind::Transient(d)
                } else {
                    FailureKind::Device(d)
                };
                feed.inject(FailureEvent { at, kind });
            }
        }
        wall_consume += t_phase.elapsed().as_secs_f64();

        // ---- elastic membership: grow one tier, drain a veteran of
        // the other
        if c.now >= next_elastic && elastic_no < cfg.elastic_points {
            next_elastic += elastic_step;
            elastic_no += 1;
            let grow = tiers[elastic_no % tiers.len()];
            let profile = match grow {
                DeviceKind::Ssd => DeviceProfile::ssd(2 << 40),
                _ => DeviceProfile::hdd(6 << 40),
            };
            let node = elastic_rng.gen_index(c.store.cluster.nodes.len());
            let (new_dev, moved, _) = c.expand_pool(node, profile, &active)?;
            report.devices_added += 1;
            report.bytes_rebalanced += moved;
            // arm the newcomer too — fresh hardware still wears out
            let at = c.now + rearm_rng.gen_exp(cfg.mtbf);
            if at < cfg.horizon {
                feed.inject(FailureEvent { at, kind: FailureKind::Device(new_dev) });
            }
            // drain a live veteran of the OTHER tier (never the device
            // we just added)
            let shrink = tiers[(elastic_no + 1) % tiers.len()];
            let victims: Vec<usize> = c.store.cluster.devices_where(|d| {
                d.profile.kind == shrink && !d.failed
            });
            if !victims.is_empty() {
                let v = victims[elastic_rng.gen_index(victims.len())];
                match c.drain_with(&active, v) {
                    Ok((bytes, _)) => {
                        report.drains_run += 1;
                        report.bytes_drained += bytes;
                    }
                    Err(_) => report.drain_errors += 1,
                }
            }
        }

        // ---- periodic full verification
        if report.ticks % cfg.verify_every == 0 {
            // sage-lint: allow(no-wall-clock, "diag wall timer: full-verify profiling, outside report identity")
            let t_phase = Instant::now();
            verify_all(&mut c, cfg, &objects, &lost);
            report.full_verifies += 1;
            wall_verify += t_phase.elapsed().as_secs_f64();
        }
    }

    // ---- end of horizon: settle and verify the whole population
    // sage-lint: allow(no-wall-clock, "diag wall timer: tail-consume profiling, outside report identity")
    let t_phase = Instant::now();
    let tail = c.consume_failure_feed(&mut feed, &active);
    tally(&mut report, &tail, &mut lost, &mut latencies);
    wall_consume += t_phase.elapsed().as_secs_f64();
    // sage-lint: allow(no-wall-clock, "diag wall timer: verify-phase profiling, outside report identity")
    let t_phase = Instant::now();
    verify_all(&mut c, cfg, &objects, &lost);
    report.full_verifies += 1;
    wall_verify += t_phase.elapsed().as_secs_f64();

    // ---- accounting invariant: every outcome is in exactly one bucket
    let tallied = report.no_action
        + report.recovered
        + report.transient_retried
        + report.aborted_by_refailure
        + report.escalated_to_repair
        + report.absorbed_by_escalation
        + report.data_loss_events
        + report.failed_recoveries;
    assert_eq!(
        tallied, report.events_consumed,
        "soak: every RecoveryOutcome must be accounted exactly once"
    );

    report.objects_lost = lost.len() as u64;
    report.bytes_written = bytes_written;
    report.writes = writes;
    report.final_now = c.now;
    report.repairs_started = c.store.ha.repairs_started;
    report.repairs_aborted = c.store.ha.repairs_aborted;
    report.feed_remaining = feed.remaining() as u64;
    let (p50, mad) = median_mad(&latencies);
    report.recovery_latency_p50 = p50;
    report.recovery_latency_mad = mad;
    let (allocs1, alloc_bytes1) = crate::util::alloc::counts();
    report.diag = SoakDiag {
        wall_total_s: t_run.elapsed().as_secs_f64(),
        wall_traffic_s: wall_traffic,
        wall_consume_s: wall_consume,
        wall_verify_s: wall_verify,
        allocs: allocs1.saturating_sub(allocs0),
        alloc_bytes: alloc_bytes1.saturating_sub(alloc_bytes0),
    };
    Ok(report)
}

/// Full-population byte check: every surviving object bit-exact
/// against its regenerated payload, every lost object still erroring.
fn verify_all(
    c: &mut Client,
    cfg: &SoakConfig,
    objects: &[SoakObject],
    lost: &HashSet<ObjectId>,
) {
    for o in objects {
        if lost.contains(&o.id) {
            assert!(
                c.read_object(&o.id, 0, o.len as u64).is_err(),
                "soak: lost object {:?} must stay unavailable",
                o.id
            );
            continue;
        }
        let got = c.read_object(&o.id, 0, o.len as u64).unwrap();
        assert_eq!(
            got,
            payload(cfg.seed, o.slot, o.version, o.len),
            "soak: object {:?} (slot {}, v{}) must read back bit-exact",
            o.id,
            o.slot,
            o.version
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A shrunk soak: every invariant above runs in-harness; here we
    /// additionally pin determinism (two runs, identical reports) and
    /// that the storm actually exercised the plane.
    fn tiny(seed: u64) -> SoakConfig {
        SoakConfig {
            seed,
            horizon: 900.0,
            n_objects: 12,
            object_stripes: 1,
            tick: 60.0,
            mtbf: 600.0,
            transient_ratio: 0.4,
            storms: 2,
            storm_window: 5.0,
            elastic_points: 1,
            rewrites_per_tick: 2,
            verify_every: 5,
        }
    }

    #[test]
    fn soak_is_deterministic_and_exercises_the_plane() {
        let a = run(&tiny(42)).unwrap();
        let b = run(&tiny(42)).unwrap();
        assert_eq!(a, b, "same config, bit-identical report");
        assert!(a.events_consumed > 0, "the feed fired");
        assert!(a.recovered > 0, "repairs ran");
        assert!(a.bytes_rebuilt > 0);
        assert!(a.writes > 0 && a.reads_verified > 0);
        assert_eq!(a.devices_added, 1, "the elastic point fired");
        assert!(a.full_verifies >= 2);
    }

    #[test]
    fn diag_is_excluded_from_report_identity() {
        let a = run(&tiny(7)).unwrap();
        let mut b = a.clone();
        b.diag.wall_total_s += 1.0e6;
        b.diag.allocs += 12345;
        assert_eq!(a, b, "diagnostics never affect report identity");
        assert!(a.diag.wall_total_s > 0.0, "the run timer ran");
        assert!(a.diag.wall_traffic_s >= 0.0);
        assert!(a.diag.wall_consume_s > 0.0, "consumer passes were timed");
        assert!(a.diag.wall_verify_s > 0.0, "verification was timed");
        // no counting allocator installed in the test binary
        assert_eq!(a.diag.allocs, 0);
    }

    #[test]
    fn soak_seeds_differ() {
        let a = run(&tiny(1)).unwrap();
        let b = run(&tiny(2)).unwrap();
        assert_ne!(a, b, "different seeds, different runs");
    }
}
