//! Multi-tenant workload generator (ISSUE 7 tentpole): N concurrent
//! tenants contending on the ONE cluster-wide scheduler, with the
//! tail-latency and fairness numbers an operator tunes
//! [`TenantShares`] against.
//!
//! Workload shape — everything a pure function of [`TenantsConfig`]:
//!
//! * **arrival models** — *open* (Poisson: exponential inter-arrival
//!   times, a tenant's demand is independent of service) and *closed*
//!   (one outstanding request per tenant: the next request is issued
//!   an exponential *think time* after the previous one completed);
//! * **heavy-tailed sizes** — request sizes are Zipf-sampled stripe
//!   counts ([`SimRng::gen_zipf`]): most requests are small, the tail
//!   is where per-tenant isolation earns its keep;
//! * **deterministic merge** — per-tenant arrival streams are merged
//!   by `(arrival time, tenant id)`, so the dispatch order (and with
//!   it the whole schedule) is bit-reproducible: same config, same
//!   [`TenantsReport`], `PartialEq` over its `f64` fields included.
//!
//! Each request rewinds the client clock to its arrival instant and
//! runs one session as its tenant — sessions genuinely overlap in
//! virtual time, so tenants contend shard-by-shard exactly as the
//! scheduler's per-tenant lanes resolve them. Per request the harness
//! records completion latency and folds the session's per-tenant
//! frontier table ([`SessionReport::tenants`]) into the tenant's
//! maximum observed device share — the number [`TenantShares::share`]
//! bounds from above (the weighted-share-bound property
//! `tests/prop_tenant.rs` pins). At the end every object is read back
//! and checked bit-exact against its regenerated payload, so the
//! report's byte digest is identical across scheduling policies
//! (tenancy on or off): the plane moves WHEN, never WHAT.
//!
//! Drivers: `sage tenants` (CLI) and `benches/ablate_tenants.rs`
//! (tenancy on/off ablation on the skewed-straggler geometry);
//! `SAGE_BENCH_QUICK=1` / `--quick` selects [`TenantsConfig::quick`].
//!
//! [`SessionReport::tenants`]: crate::clovis::SessionReport

use crate::bench::testkit;
use crate::clovis::Client;
use crate::config::Testbed;
use crate::error::Result;
use crate::metrics::Stats;
use crate::mero::ObjectId;
use crate::sim::clock::SimTime;
use crate::sim::rng::SimRng;
use crate::sim::sched::{TenantId, TenantShares, DEFAULT_TENANT};

/// How a tenant issues its requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalModel {
    /// Poisson arrivals: exponential inter-arrival times with this
    /// mean (seconds), independent of service — queues can build.
    Open { mean_interarrival: f64 },
    /// One outstanding request per tenant: the next arrival is the
    /// previous completion plus an exponential think time with this
    /// mean (seconds) — demand self-throttles under contention.
    Closed { think: f64 },
}

/// Knobs of one generator run. The report is a pure function of this
/// struct — keep every field deterministic (no wall-clock anywhere).
#[derive(Debug, Clone)]
pub struct TenantsConfig {
    /// Master seed; all RNG streams fork from it.
    pub seed: u64,
    /// One weight per tenant (tenant 0 is [`DEFAULT_TENANT`]
    /// re-weighted; the rest are admitted via
    /// [`Client::register_tenant`]). Two or more activate the plane.
    pub weights: Vec<f64>,
    /// Arrival model shared by every tenant (streams stay independent:
    /// each tenant forks its own RNG).
    pub arrival: ArrivalModel,
    /// Requests each tenant issues over the run.
    pub requests_per_tenant: usize,
    /// Objects each tenant rewrites round-robin.
    pub objects_per_tenant: usize,
    /// Heavy-tail cap: request sizes are `1 + Zipf(max_stripes)` full
    /// stripes.
    pub max_stripes: u64,
    /// Zipf skew in (0, 1): higher = heavier tail.
    pub zipf_theta: f64,
    /// `false` leaves the tenant plane inactive (every session runs as
    /// [`DEFAULT_TENANT`]) — the ablation baseline: same merged
    /// arrival order, FIFO contention instead of per-tenant lanes.
    pub tenancy: bool,
}

impl TenantsConfig {
    /// CI smoke shape: 3 tenants at 4:2:1, a few dozen requests —
    /// the same invariants, well under a second of wall clock.
    pub fn quick(seed: u64) -> TenantsConfig {
        TenantsConfig {
            seed,
            weights: vec![4.0, 2.0, 1.0],
            arrival: ArrivalModel::Open { mean_interarrival: 0.4 },
            requests_per_tenant: 16,
            objects_per_tenant: 2,
            max_stripes: 4,
            zipf_theta: 0.9,
            tenancy: true,
        }
    }

    /// The contended shape: 6 tenants with an 8:4:2:1:1:1 skew and a
    /// longer heavy tail.
    pub fn full(seed: u64) -> TenantsConfig {
        TenantsConfig {
            seed,
            weights: vec![8.0, 4.0, 2.0, 1.0, 1.0, 1.0],
            arrival: ArrivalModel::Open { mean_interarrival: 0.25 },
            requests_per_tenant: 64,
            objects_per_tenant: 4,
            max_stripes: 8,
            zipf_theta: 0.9,
            tenancy: true,
        }
    }
}

/// One tenant's latency/throughput digest.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantLatency {
    pub tenant: TenantId,
    pub weight: f64,
    pub requests: u64,
    pub bytes: u64,
    /// Completion-latency quantiles (seconds of virtual time from
    /// arrival to completion).
    pub p50: f64,
    pub p99: f64,
    pub p999: f64,
    pub mean: f64,
    /// Maximum device-time share this tenant was observed holding on
    /// any shard in any of its sessions
    /// ([`TenantShardReport::observed_share`]); the cluster's
    /// [`TenantShares::share`] bounds it from above. 0.0 while the
    /// plane is inactive (no lanes, no rows).
    ///
    /// [`TenantShardReport::observed_share`]: crate::sim::sched::TenantShardReport::observed_share
    pub max_observed_share: f64,
}

/// Everything one generator run measured. Bit-for-bit reproducible
/// from the config — drivers assert two runs compare equal.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantsReport {
    /// One digest per configured tenant, in tenant-id order.
    pub per_tenant: Vec<TenantLatency>,
    /// Jain fairness index over weight-normalized tenant throughput
    /// (`bytes / weight`): 1.0 = perfectly weighted-fair, `1/N` =
    /// one tenant starved the rest.
    pub jain: f64,
    pub requests: u64,
    pub total_bytes: u64,
    /// Last completion minus first arrival (virtual seconds).
    pub makespan: SimTime,
    /// CRC32 over every object's final read-back, in `(tenant, slot)`
    /// order — identical across scheduling policies (tenancy on/off):
    /// contention changes WHEN, never WHAT.
    pub bytes_crc: u32,
    pub final_now: SimTime,
}

/// One tracked object: payloads are regenerated from
/// `(seed, tenant, slot, version)`, never stored by the harness.
struct TenantObject {
    id: ObjectId,
    version: u64,
    /// Length of the live payload (the last write's), in bytes.
    len: usize,
}

/// Deterministic payload for `(seed, tenant, slot, version)`.
fn payload(seed: u64, tenant: usize, slot: usize, version: u64, len: usize) -> Vec<u8> {
    let mut rng = SimRng::new(
        seed ^ (tenant as u64).wrapping_mul(0xA24BAED4963EE407)
            ^ (slot as u64).wrapping_mul(0x9E3779B97F4A7C15)
            ^ version.wrapping_mul(0xD1B54A32D192ED03),
    );
    let mut d = vec![0u8; len];
    rng.fill_bytes(&mut d);
    d
}

/// Run the generator on the default testbed
/// ([`Testbed::sage_prototype`]).
pub fn run(cfg: &TenantsConfig) -> Result<TenantsReport> {
    run_with(Client::new_sim(Testbed::sage_prototype()), cfg)
}

/// Run the generator on a caller-built client (the bench supplies the
/// skewed-straggler geometry this way). Invariant violations panic
/// (the harness is the test); storage errors surface as `Err`.
pub fn run_with(mut c: Client, cfg: &TenantsConfig) -> Result<TenantsReport> {
    let n = cfg.weights.len();
    assert!(n >= 1, "at least one tenant");
    assert!(cfg.requests_per_tenant >= 1 && cfg.objects_per_tenant >= 1);

    // ---- admission: tenant 0 is DEFAULT_TENANT re-weighted, the rest
    // are registered. With tenancy off every session dispatches as
    // DEFAULT_TENANT on an inactive plane (the FIFO baseline).
    let ids: Vec<TenantId> = if cfg.tenancy {
        let mut shares = TenantShares::single();
        shares.set_weight(DEFAULT_TENANT, cfg.weights[0]);
        let mut ids = vec![DEFAULT_TENANT];
        for &w in &cfg.weights[1..] {
            ids.push(shares.register(w));
        }
        c.store.cluster.tenants = shares;
        ids
    } else {
        vec![DEFAULT_TENANT; n]
    };

    // ---- population: every tenant's objects exist before the clock
    // starts, so request latency measures scheduling, not creation
    let mut rng = SimRng::new(cfg.seed);
    let stripe = 4 * testkit::UNIT; // K=4 data units per stripe
    let mut objects: Vec<Vec<TenantObject>> = Vec::with_capacity(n);
    for k in 0..n {
        let mut per = Vec::with_capacity(cfg.objects_per_tenant);
        for slot in 0..cfg.objects_per_tenant {
            let id = c.create_object_with(testkit::BS, testkit::raid(4, 1))?;
            let len = stripe as usize;
            c.write_object(&id, 0, &payload(cfg.seed, k, slot, 0, len))?;
            per.push(TenantObject { id, version: 0, len });
        }
        objects.push(per);
    }
    let t0 = c.now;

    // ---- per-tenant streams: independent RNGs for arrivals and sizes
    let mut arrive_rng: Vec<SimRng> =
        (0..n).map(|k| rng.fork(100 + k as u64)).collect();
    let mut size_rng: Vec<SimRng> =
        (0..n).map(|k| rng.fork(200 + k as u64)).collect();
    let first_gap = |r: &mut SimRng| match cfg.arrival {
        ArrivalModel::Open { mean_interarrival } => r.gen_exp(mean_interarrival),
        ArrivalModel::Closed { think } => r.gen_exp(think),
    };
    let mut next_at: Vec<Option<SimTime>> =
        arrive_rng.iter_mut().map(|r| Some(t0 + first_gap(r))).collect();
    let mut issued = vec![0usize; n];
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut bytes = vec![0u64; n];
    let mut max_share = vec![0.0f64; n];
    let mut makespan_end = t0;

    // ---- deterministic merge: always dispatch the earliest pending
    // arrival; ties break toward the lower tenant id
    loop {
        let mut pick: Option<(usize, SimTime)> = None;
        for (k, at) in next_at.iter().enumerate() {
            if let Some(t) = *at {
                let better = match pick {
                    Some((_, best)) => t < best,
                    None => true,
                };
                if better {
                    pick = Some((k, t));
                }
            }
        }
        let Some((k, t)) = pick else { break };

        // heavy-tailed request: 1 + Zipf stripes, rank 0 hot
        let stripes = 1 + size_rng[k].gen_zipf(cfg.max_stripes, cfg.zipf_theta);
        let len = (stripes * stripe) as usize;
        let slot = issued[k] % cfg.objects_per_tenant;
        let o = &mut objects[k][slot];
        let data = payload(cfg.seed, k, slot, o.version + 1, len);

        // dispatch INTO the contention window: the clock rewinds to
        // the arrival instant, so this session's epoch overlaps every
        // still-busy shard of earlier sessions
        c.now = t;
        let mut s = c.session_as(ids[k])?;
        let h = s.write_owned(&o.id, vec![(0, data)]);
        let rep = s.run()?;
        let done = rep.completed[h.index()];
        latencies[k].push(done - t);
        bytes[k] += len as u64;
        makespan_end = makespan_end.max(done);
        for shard in &rep.tenants {
            max_share[k] = max_share[k].max(shard.observed_share(ids[k]));
        }
        o.version += 1;
        o.len = len;

        issued[k] += 1;
        next_at[k] = if issued[k] >= cfg.requests_per_tenant {
            None
        } else {
            match cfg.arrival {
                ArrivalModel::Open { mean_interarrival } => {
                    Some(t + arrive_rng[k].gen_exp(mean_interarrival))
                }
                ArrivalModel::Closed { think } => {
                    Some(done + arrive_rng[k].gen_exp(think))
                }
            }
        };
    }

    // ---- bytes survive contention: every object reads back bit-exact
    // against its regenerated payload; the digest is policy-invariant
    let mut crc = crc32fast::Hasher::new();
    for (k, per) in objects.iter().enumerate() {
        for (slot, o) in per.iter().enumerate() {
            let got = c.read_object(&o.id, 0, o.len as u64)?;
            assert_eq!(
                got,
                payload(cfg.seed, k, slot, o.version, o.len),
                "tenants: object of tenant {k} slot {slot} must read \
                 back bit-exact"
            );
            crc.update(&got);
        }
    }

    // ---- digests: per-tenant quantiles + Jain over bytes/weight
    let per_tenant: Vec<TenantLatency> = (0..n)
        .map(|k| {
            let mut s = Stats::new();
            for &l in &latencies[k] {
                s.push(l);
            }
            TenantLatency {
                tenant: ids[k],
                weight: cfg.weights[k],
                requests: latencies[k].len() as u64,
                bytes: bytes[k],
                p50: s.quantile(0.5),
                p99: s.quantile(0.99),
                p999: s.quantile(0.999),
                mean: s.mean(),
                max_observed_share: max_share[k],
            }
        })
        .collect();
    let xs: Vec<f64> = (0..n)
        .map(|k| bytes[k] as f64 / cfg.weights[k].max(f64::MIN_POSITIVE))
        .collect();
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    let jain = if sq <= 0.0 {
        1.0
    } else {
        (sum * sum) / (n as f64 * sq)
    };

    Ok(TenantsReport {
        per_tenant,
        jain,
        requests: issued.iter().map(|&i| i as u64).sum(),
        total_bytes: bytes.iter().sum(),
        makespan: makespan_end - t0,
        bytes_crc: crc.finalize(),
        final_now: c.now,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(seed: u64, tenancy: bool, arrival: ArrivalModel) -> TenantsConfig {
        TenantsConfig {
            seed,
            weights: vec![3.0, 1.0],
            arrival,
            requests_per_tenant: 6,
            objects_per_tenant: 2,
            max_stripes: 3,
            zipf_theta: 0.9,
            tenancy,
        }
    }

    #[test]
    fn generator_is_deterministic_and_policy_moves_when_not_what() {
        let open = ArrivalModel::Open { mean_interarrival: 0.3 };
        let a = run(&tiny(7, true, open)).unwrap();
        let b = run(&tiny(7, true, open)).unwrap();
        assert_eq!(a, b, "same config, bit-identical report");
        assert_eq!(a.requests, 12);
        assert!(a.total_bytes > 0 && a.makespan > 0.0);
        assert!(a.jain > 0.0 && a.jain <= 1.0 + 1e-12);
        // the plane was active: shares observed and bounded
        let shares = {
            let mut s = TenantShares::single();
            s.set_weight(DEFAULT_TENANT, 3.0);
            s.register(1.0);
            s
        };
        for t in &a.per_tenant {
            assert!(t.max_observed_share > 0.0, "lanes really ran");
            assert!(t.max_observed_share <= shares.share(t.tenant) + 1e-9);
        }
        // the baseline schedules differently but lands the same bytes
        let base = run(&tiny(7, false, open)).unwrap();
        assert_eq!(base.bytes_crc, a.bytes_crc, "WHEN moved, WHAT did not");
        assert_eq!(base.total_bytes, a.total_bytes);
        assert!(base.per_tenant.iter().all(|t| t.max_observed_share == 0.0));
        // different seeds, different runs
        let c = run(&tiny(8, true, open)).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn closed_model_self_throttles_and_stays_deterministic() {
        let closed = ArrivalModel::Closed { think: 0.2 };
        let a = run(&tiny(11, true, closed)).unwrap();
        let b = run(&tiny(11, true, closed)).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.requests, 12);
        // closed arrivals wait for completions: no request can ever
        // observe more than one in flight per tenant, so per-tenant
        // p999 stays at the scale of a single service time — still
        // finite and positive
        for t in &a.per_tenant {
            assert!(t.p50 > 0.0 && t.p999 >= t.p99 && t.p99 >= t.p50);
        }
    }
}
