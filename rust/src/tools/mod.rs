//! SAGE tools layer (§3.2.3): I/O profiling and optimized data
//! movement.
//!
//! * [`rthms`] — the RTHMS data-placement recommender: analyzes access
//!   patterns and recommends the tier for each memory/storage object.
//! * [`analytics`] — the data-analytics connector (the role Apache
//!   Flink plays in the SAGE project): a small dataflow engine whose
//!   sources are Clovis objects and whose pipelines push computation
//!   into storage via function shipping where possible.
//! * [`soak`] — the long-horizon failure-storm soak harness: hours of
//!   virtual time of continuous traffic, correlated storms, and
//!   elastic pool membership, with durability invariants checked
//!   in-harness (driven by `sage soak` and `benches/soak_storm.rs`).
//! * [`tenants`] — the multi-tenant workload generator: N contending
//!   tenants on the one cluster-wide scheduler, open/closed arrival
//!   models, heavy-tailed sizes, per-tenant tail latency and Jain
//!   fairness (driven by `sage tenants` and
//!   `benches/ablate_tenants.rs`).
//! * [`lint`] — the determinism & invariant static-analysis pass: a
//!   hand-rolled tokenizer plus six token-pattern rules that keep
//!   wall clocks, hash-order leaks, scheduler bypasses, recovery-plane
//!   panics, ambient entropy, and oracle edits out of the tree (driven
//!   by `sage lint` and the CI `lint` job).
//!
//! Module map (ARCHITECTURE.md §Module map rows `tools/`): both tools
//! are FDMI/Clovis *consumers*, not core-path code — RTHMS ingests the
//! telemetry feed (`clovis::fdmi`) to build its recommendations, and
//! analytics pipelines execute through `clovis::fshipping` sessions,
//! so their reads ride the same sharded scheduler (and QoS split —
//! ARCHITECTURE.md §QoS plane) as every other foreground op. The
//! recommendations RTHMS emits are the manual counterpart of the
//! HSM's automated heat-driven planning (`crate::hsm`); OPERATIONS.md
//! describes how operators combine the two with the recovery plane's
//! decision flow.

pub mod analytics;
pub mod lint;
pub mod rthms;
pub mod soak;
pub mod tenants;
