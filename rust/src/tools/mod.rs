//! SAGE tools layer (§3.2.3): I/O profiling and optimized data
//! movement.
//!
//! * [`rthms`] — the RTHMS data-placement recommender: analyzes access
//!   patterns and recommends the tier for each memory/storage object.
//! * [`analytics`] — the data-analytics connector (the role Apache
//!   Flink plays in the SAGE project): a small dataflow engine whose
//!   sources are Clovis objects and whose pipelines push computation
//!   into storage via function shipping where possible.

pub mod analytics;
pub mod rthms;
