//! RTHMS: data-placement recommendations on heterogeneous memory /
//! storage systems (§3.2.3, ref [12]).
//!
//! "We designed and developed a tool, called RTHMS, that analyzes
//! parallel applications and provides recommendations to the programmer
//! about the data placement of memory objects on heterogeneous memory
//! systems. Our tool only requires the application binary and the
//! characteristics of each memory technology (e.g., memory latency and
//! bandwidth) available in the system."
//!
//! Our version consumes the equivalent of the instrumented trace — the
//! FDMI access stream — and the device characteristics from the
//! [`Testbed`], scores each object per tier (access intensity ×
//! latency/bandwidth sensitivity vs capacity pressure), and emits
//! ranked placement recommendations.

use std::collections::HashMap;

use crate::clovis::fdmi::FdmiRecord;
use crate::config::Testbed;
use crate::mero::object::ObjectId;
use crate::sim::device::{DeviceKind, DeviceProfile};

/// Per-object access profile accumulated from the trace.
#[derive(Debug, Clone, Default)]
pub struct AccessProfile {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Mean access size (small = latency-sensitive, large = bandwidth-
    /// sensitive) — the RTHMS intensity heuristic.
    pub accesses: u64,
}

impl AccessProfile {
    /// Mean bytes per access.
    pub fn mean_access(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            (self.bytes_read + self.bytes_written) as f64 / self.accesses as f64
        }
    }

    /// Read share of traffic.
    pub fn read_ratio(&self) -> f64 {
        let total = self.bytes_read + self.bytes_written;
        if total == 0 {
            0.5
        } else {
            self.bytes_read as f64 / total as f64
        }
    }
}

/// One placement recommendation.
#[derive(Debug, Clone, PartialEq)]
pub struct Recommendation {
    pub obj: ObjectId,
    pub tier: DeviceKind,
    /// Estimated mean access time on the recommended tier, seconds.
    pub est_access: f64,
    /// Ranked alternatives (tier, est access time), best first.
    pub alternatives: Vec<(DeviceKind, f64)>,
}

/// The analyzer.
#[derive(Debug, Default)]
pub struct Rthms {
    profiles: HashMap<ObjectId, AccessProfile>,
}

impl Rthms {
    /// Fresh analyzer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Ingest trace records (FDMI stream = the instrumented trace).
    pub fn ingest(&mut self, records: &[FdmiRecord]) {
        for rec in records {
            match rec {
                FdmiRecord::ObjectRead { obj, len, .. } => {
                    let p = self.profiles.entry(*obj).or_default();
                    p.reads += 1;
                    p.accesses += 1;
                    p.bytes_read += len;
                }
                FdmiRecord::ObjectWritten { obj, len, .. } => {
                    let p = self.profiles.entry(*obj).or_default();
                    p.writes += 1;
                    p.accesses += 1;
                    p.bytes_written += len;
                }
                FdmiRecord::ObjectDeleted { obj, .. } => {
                    self.profiles.remove(obj);
                }
                _ => {}
            }
        }
    }

    /// Estimated mean access time of `p` on a device `d`.
    fn est(p: &AccessProfile, d: &DeviceProfile) -> f64 {
        let mean = p.mean_access().max(1.0);
        let rw = p.read_ratio();
        let bw = rw * d.read_bw + (1.0 - rw) * d.write_bw;
        d.latency + mean / bw
    }

    /// Recommend a tier for every profiled object. Capacity pressure:
    /// objects are ranked by access intensity; the fastest tier takes
    /// the most intense objects until `fast_budget` bytes are assigned,
    /// mirroring RTHMS's "hot data first into the scarce fast memory".
    pub fn recommend(&self, tb: &Testbed, fast_budget: u64) -> Vec<Recommendation> {
        // one representative profile per kind present in the testbed
        let mut kinds: Vec<(DeviceKind, &DeviceProfile)> = Vec::new();
        for p in &tb.storage {
            if !kinds.iter().any(|(k, _)| *k == p.kind) {
                kinds.push((p.kind, p));
            }
        }
        kinds.sort_by_key(|(k, _)| k.tier());

        // rank objects by traffic intensity
        let mut ranked: Vec<(&ObjectId, &AccessProfile)> =
            self.profiles.iter().collect();
        ranked.sort_by_key(|(_, p)| {
            std::cmp::Reverse(p.bytes_read + p.bytes_written)
        });

        let mut used_fast = 0u64;
        let mut out = Vec::with_capacity(ranked.len());
        for (obj, p) in ranked {
            let mut scored: Vec<(DeviceKind, f64)> = kinds
                .iter()
                .map(|(k, d)| (*k, Self::est(p, d)))
                .collect();
            scored.sort_by(|a, b| a.1.total_cmp(&b.1));
            // capacity pressure: skip the fastest tier once the budget
            // is consumed
            let footprint = p.bytes_written.max(p.bytes_read / 4).max(4096);
            let pick = scored
                .iter()
                .find(|(k, _)| {
                    if k.tier() == kinds[0].0.tier() {
                        used_fast + footprint <= fast_budget
                    } else {
                        true
                    }
                })
                .copied()
                .unwrap_or(scored[0]);
            if pick.0.tier() == kinds[0].0.tier() {
                used_fast += footprint;
            }
            out.push(Recommendation {
                obj: *obj,
                tier: pick.0,
                est_access: pick.1,
                alternatives: scored,
            });
        }
        out
    }

    /// Profiled object count.
    pub fn tracked(&self) -> usize {
        self.profiles.len()
    }

    /// Borrow a profile.
    pub fn profile(&self, obj: ObjectId) -> Option<&AccessProfile> {
        self.profiles.get(&obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read_rec(obj: u64, len: u64, at: f64) -> FdmiRecord {
        FdmiRecord::ObjectRead { obj: ObjectId(obj), offset: 0, len, at }
    }

    #[test]
    fn intense_objects_get_fast_tier_until_budget() {
        let mut r = Rthms::new();
        // obj 1: hammered; obj 2: moderate; obj 3: barely touched
        let mut recs = Vec::new();
        for i in 0..100 {
            recs.push(read_rec(1, 1 << 20, i as f64));
        }
        for i in 0..10 {
            recs.push(read_rec(2, 1 << 20, i as f64));
        }
        recs.push(read_rec(3, 4096, 0.0));
        r.ingest(&recs);
        let tb = Testbed::sage_prototype();
        // fast budget fits obj1's footprint (100MiB/4 = 25MiB) only
        let out = r.recommend(&tb, 26 << 20);
        let tier_of = |o: u64| {
            out.iter().find(|x| x.obj == ObjectId(o)).unwrap().tier
        };
        assert_eq!(tier_of(1), DeviceKind::Nvram, "hottest goes fastest");
        assert_ne!(tier_of(2), DeviceKind::Nvram, "budget exhausted by obj1");
    }

    #[test]
    fn estimates_reflect_device_characteristics() {
        let mut r = Rthms::new();
        r.ingest(&[read_rec(1, 1 << 20, 0.0)]);
        let tb = Testbed::sage_prototype();
        let rec = &r.recommend(&tb, u64::MAX)[0];
        // alternatives sorted fastest-first; NVRAM beats SMR
        let first = rec.alternatives.first().unwrap();
        let last = rec.alternatives.last().unwrap();
        assert!(first.1 < last.1);
        assert_eq!(first.0, DeviceKind::Nvram);
    }

    #[test]
    fn deleted_objects_dropped() {
        let mut r = Rthms::new();
        r.ingest(&[
            read_rec(5, 4096, 0.0),
            FdmiRecord::ObjectDeleted { obj: ObjectId(5), at: 1.0 },
        ]);
        assert_eq!(r.tracked(), 0);
    }

    #[test]
    fn profile_statistics() {
        let mut r = Rthms::new();
        r.ingest(&[
            read_rec(9, 1000, 0.0),
            FdmiRecord::ObjectWritten { obj: ObjectId(9), offset: 0, len: 3000, at: 1.0 },
        ]);
        let p = r.profile(ObjectId(9)).unwrap();
        assert_eq!(p.reads, 1);
        assert_eq!(p.writes, 1);
        assert_eq!(p.mean_access(), 2000.0);
        assert_eq!(p.read_ratio(), 0.25);
    }
}
