//! Data-analytics connector (§3.2.3 "Data Analytics Tools").
//!
//! "Apache Flink, the data analytics tool employed in the SAGE project,
//! will work on top of the Clovis access interface through Flink
//! connectors for Clovis. Using Flink enables the deployment of data
//! analytics jobs on top of Mero."
//!
//! A small dataflow engine playing Flink's role: a [`Pipeline`] of
//! map/filter/aggregate stages over f32 record streams sourced from
//! Clovis objects. The connector's key optimization mirrors the SAGE
//! design: *source-side pushdown* — when the leading stages are
//! expressible as a shipped function (histogram, filter-count), they
//! run in storage via function shipping and only the small result
//! crosses the network.

use crate::clovis::{Client, FnOutput, FunctionKind};
use crate::error::Result;
use crate::mero::ObjectId;

/// One dataflow stage.
pub enum Stage {
    /// Element-wise transform.
    Map(Box<dyn Fn(f32) -> f32>),
    /// Keep elements matching the predicate.
    Filter(Box<dyn Fn(f32) -> bool>),
}

/// Terminal aggregation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sink {
    Count,
    Sum,
    Mean,
    Max,
    /// 64-bin histogram over [lo, hi).
    Histogram { lo: f32, hi: f32 },
}

/// Result of running a pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum JobResult {
    Scalar(f64),
    Histogram(Vec<f32>),
}

/// Execution strategy chosen by the planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plan {
    /// Whole job shipped to storage (zero data movement).
    InStorage,
    /// Data pulled to the client, stages run locally.
    ClientSide,
}

/// A dataflow job over one source object.
pub struct Pipeline {
    stages: Vec<Stage>,
    sink: Sink,
}

impl Pipeline {
    /// Start a pipeline ending in `sink`.
    pub fn new(sink: Sink) -> Pipeline {
        Pipeline { stages: Vec::new(), sink }
    }

    /// Append a map stage.
    pub fn map<F: Fn(f32) -> f32 + 'static>(mut self, f: F) -> Self {
        self.stages.push(Stage::Map(Box::new(f)));
        self
    }

    /// Append a filter stage.
    pub fn filter<F: Fn(f32) -> bool + 'static>(mut self, f: F) -> Self {
        self.stages.push(Stage::Filter(Box::new(f)));
        self
    }

    /// Planner: a stage-free histogram job is pushable into storage.
    pub fn plan(&self) -> Plan {
        if self.stages.is_empty() {
            if let Sink::Histogram { .. } = self.sink {
                return Plan::InStorage;
            }
        }
        Plan::ClientSide
    }

    /// Execute over the f32 records stored in `obj` (logical length
    /// `n_records`). Returns the result and the plan used.
    pub fn run(
        &self,
        client: &mut Client,
        obj: ObjectId,
        n_records: u64,
    ) -> Result<(JobResult, Plan)> {
        match self.plan() {
            Plan::InStorage => {
                let Sink::Histogram { lo, hi } = self.sink else {
                    unreachable!("planner only pushes histograms")
                };
                let r = client
                    .ship_to_object(obj, FunctionKind::Histogram { lo, hi })?;
                let counts = match r.output {
                    FnOutput::Histogram(c) => c,
                    _ => vec![0.0; 64],
                };
                Ok((JobResult::Histogram(counts), Plan::InStorage))
            }
            Plan::ClientSide => {
                // pull the records (this is what pushdown avoids)
                let bytes = n_records * 4;
                let padded = bytes.div_ceil(4096) * 4096;
                let raw = client.read_object(&obj, 0, padded)?;
                let mut vals: Vec<f32> = raw[..bytes as usize]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                for stage in &self.stages {
                    match stage {
                        Stage::Map(f) => {
                            for v in &mut vals {
                                *v = f(*v);
                            }
                        }
                        Stage::Filter(f) => vals.retain(|v| f(*v)),
                    }
                }
                let res = match self.sink {
                    Sink::Count => JobResult::Scalar(vals.len() as f64),
                    Sink::Sum => {
                        JobResult::Scalar(vals.iter().map(|&v| v as f64).sum())
                    }
                    Sink::Mean => {
                        let s: f64 = vals.iter().map(|&v| v as f64).sum();
                        JobResult::Scalar(s / vals.len().max(1) as f64)
                    }
                    Sink::Max => JobResult::Scalar(
                        vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
                            as f64,
                    ),
                    Sink::Histogram { lo, hi } => {
                        let mut counts = vec![0f32; 64];
                        let w = (hi - lo) / 64.0;
                        for v in &vals {
                            let i = (((v - lo) / w).floor() as i64).clamp(0, 63);
                            counts[i as usize] += 1.0;
                        }
                        JobResult::Histogram(counts)
                    }
                };
                Ok((res, Plan::ClientSide))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Testbed;

    fn store_records(client: &mut Client, vals: &[f32]) -> ObjectId {
        let obj = client.create_object(4096).unwrap();
        let mut bytes = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.resize(bytes.len().div_ceil(4 * 65536) * (4 * 65536), 0);
        client.write_object(&obj, 0, &bytes).unwrap();
        obj
    }

    #[test]
    fn histogram_pushes_into_storage() {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let vals: Vec<f32> = (0..10_000).map(|i| (i % 64) as f32 + 0.5).collect();
        let obj = store_records(&mut c, &vals);
        let job = Pipeline::new(Sink::Histogram { lo: 0.0, hi: 64.0 });
        assert_eq!(job.plan(), Plan::InStorage);
        let (res, plan) = job.run(&mut c, obj, 10_000).unwrap();
        assert_eq!(plan, Plan::InStorage);
        match res {
            // padding zeros land in bin 0 — every real record counted
            JobResult::Histogram(counts) => {
                assert!(counts.iter().sum::<f32>() >= 10_000.0);
                assert_eq!(counts[5], 157.0); // 10_000/64 + partials
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn map_filter_aggregate_client_side() {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let vals: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        let obj = store_records(&mut c, &vals);
        let job = Pipeline::new(Sink::Sum)
            .map(|v| v * 2.0)
            .filter(|v| v > 100.0); // keeps 2*51..2*100
        let (res, plan) = job.run(&mut c, obj, 100).unwrap();
        assert_eq!(plan, Plan::ClientSide);
        // sum of 2i for i in 51..=100 = 2 * (51+..+100) = 2*3775 = 7550
        assert_eq!(res, JobResult::Scalar(7550.0));
    }

    #[test]
    fn mean_and_max_sinks() {
        let mut c = Client::new_sim(Testbed::sage_prototype());
        let obj = store_records(&mut c, &[1.0, 2.0, 3.0, 4.0]);
        let (mean, _) = Pipeline::new(Sink::Mean)
            .filter(|v| v > 0.0) // drop padding zeros
            .run(&mut c, obj, 4 * 65536 / 4)
            .unwrap();
        assert_eq!(mean, JobResult::Scalar(2.5));
        let (max, _) = Pipeline::new(Sink::Max)
            .run(&mut c, obj, 4)
            .unwrap();
        assert_eq!(max, JobResult::Scalar(4.0));
    }
}
