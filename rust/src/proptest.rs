//! Property-test harness (offline substitute for the proptest crate).
//! In-tree substrate (ARCHITECTURE.md §Module map); backs the
//! differential oracles in `rust/tests/` (§3.2.1 SNS engines, sharded
//! scheduler).
//!
//! [`prop_check`] runs a property over N deterministically-generated
//! random cases; on failure it performs greedy shrinking via the
//! case's [`Shrink`] implementation and reports the minimal failing
//! case. Coordinator invariants (layout round-trip, DTM atomicity, KV
//! NEXT ordering, stripe reconstruction, HSM no-loss) are checked with
//! this in `rust/tests/prop_invariants.rs`.

use crate::sim::rng::SimRng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, largest reduction first. Empty = atomic.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![self / 2, self - 1]
        }
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![self / 2, self - 1]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halve
        out.push(self[..self.len() / 2].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink first element
        if let Some(first_shrunk) = self[0].shrink().into_iter().next() {
            let mut v = self.clone();
            v[0] = first_shrunk;
            out.push(v);
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Extra entropy folded into every [`prop_check`] RNG, from the
/// `SAGE_PROP_SEED` environment variable (0 when unset or unparsable).
/// CI's seed-matrix job sets it to run each property suite over
/// several independent sampling streams; the default stream stays
/// exactly what it always was.
fn env_seed() -> u64 {
    std::env::var("SAGE_PROP_SEED")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0)
}

/// Run `prop` over `cases` random inputs from `gen`. Panics with the
/// (shrunken) minimal counterexample on failure. Set `SAGE_PROP_SEED`
/// to an integer to re-seed every property's sampling stream (the
/// value is mixed into the per-property seed; unset = stream 0).
pub fn prop_check<T, G, P>(name: &str, cases: u32, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut SimRng) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = SimRng::new(
        (0x5EED_u64 ^ name.len() as u64)
            .wrapping_add(env_seed().wrapping_mul(0x9E3779B97F4A7C15)),
    );
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop);
            panic!(
                "property '{name}' failed (case {case}); minimal \
                 counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> bool>(mut failing: T, prop: &P) -> T {
    'outer: loop {
        for cand in failing.shrink() {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        return failing;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check("add-commutes", 100, |r| (r.gen_range(100), r.gen_range(100)), |(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "all-below-50")]
    fn failing_property_shrinks() {
        prop_check(
            "all-below-50",
            200,
            |r| r.gen_range(100),
            |&x| x < 50,
        );
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // property: all vecs have length < 3. counterexample should
        // shrink towards length exactly 3.
        let failing = vec![9u64, 9, 9, 9, 9, 9, 9, 9];
        let minimal = shrink_loop(failing, &|v: &Vec<u64>| v.len() < 3);
        assert_eq!(minimal.len(), 3);
    }
}
