//! Perf-pass driver: wall-clock measurement of the L3 hot paths.
use sage::bench::Bencher;
use sage::config::Testbed;
use sage::mero::{sns, Layout, MeroStore};
use sage::sim::device::DeviceKind;
use sage::sim::rng::SimRng;
use sage::sim::cache::PageCache;

fn main() {
    let mut rng = SimRng::new(1);
    // 1. CPU parity (SNS fallback hot loop), 8 x 64KiB units
    let units: Vec<Vec<u8>> = (0..8).map(|_| { let mut v = vec![0u8; 65536]; rng.fill_bytes(&mut v); v }).collect();
    let m = Bencher::new("cpu_parity_8x64k").iters(5, 50).wall(|| sns::cpu_parity(&units));
    println!("{}  ({})", m.summary(), m.throughput(8*65536));

    // 2. SNS write path end-to-end (1 MiB object write, no kernel)
    let mut data = vec![0u8; 1 << 20]; rng.fill_bytes(&mut data);
    let m = Bencher::new("sns_write_1MiB_4+1").iters(3, 20).wall(|| {
        let mut s = MeroStore::new(Testbed::sage_prototype().build_cluster());
        let id = s.create_object(4096, Layout::Raid{data:4,parity:1,unit:65536,tier:DeviceKind::Ssd}).unwrap();
        s.write_object(id, 0, &data, 0.0, None).unwrap()
    });
    println!("{}  ({})", m.summary(), m.throughput(1<<20));

    // 3. SNS read path
    let mut s = MeroStore::new(Testbed::sage_prototype().build_cluster());
    let id = s.create_object(4096, Layout::Raid{data:4,parity:1,unit:65536,tier:DeviceKind::Ssd}).unwrap();
    s.write_object(id, 0, &data, 0.0, None).unwrap();
    let m = Bencher::new("sns_read_1MiB").iters(3, 20).wall(|| {
        s.read_object(id, 0, 1<<20, 1.0).unwrap().0
    });
    println!("{}  ({})", m.summary(), m.throughput(1<<20));

    // 4. PageCache ops (the PGAS/STREAM inner loop)
    let mut c = PageCache::new(1<<30, 4096);
    let m = Bencher::new("cache_write_64B_hot").iters(3, 20).wall(|| {
        let mut acc = 0u64;
        for i in 0..100_000u64 { acc += c.write((i*64) % (1<<20), 64).hit; }
        acc
    });
    println!("{} (100k writes/iter => {:.0} ns/op)", m.summary(), m.median * 1e9 / 1e5);

    // 5. STREAM bench wall time (fig3 inner loop at 100M elems)
    let tb = Testbed::blackdog();
    let m = Bencher::new("fig3_stream_100M_storage").iters(1, 5).wall(|| {
        sage::apps::stream::run(&tb, sage::pgas::WindowKind::Storage(sage::pgas::StorageTarget::Hdd), 100, 1).unwrap()
    });
    println!("{}", m.summary());

    // 6. DHT run (fig4 inner loop)
    let cfg = sage::apps::dht::DhtConfig { ranks: 8, local_volume: 50_000, ops_per_rank: 50_000, sync_interval: u64::MAX };
    let m = Bencher::new("fig4_dht_8x50k").iters(1, 5).wall(|| {
        sage::apps::dht::run(&tb, sage::pgas::WindowKind::Storage(sage::pgas::StorageTarget::Hdd), &cfg).unwrap()
    });
    println!("{}", m.summary());

    // 7. streams push loop (fig7 inner)
    let bes = Testbed::beskow();
    let m = Bencher::new("fig7_scaling_2048x20").iters(1, 3).wall(|| {
        sage::apps::ipic3d::run_scaling(&bes, 2048, 20)
    });
    println!("{}", m.summary());
}
