//! Advanced Views & gateways (§3.2.1 / §3.2.3 / §3.2.4): the same
//! storage serving POSIX, S3 and HDF5 interfaces, an analytics pipeline
//! with in-storage pushdown, and RTHMS placement recommendations from
//! the live FDMI trace.
//!
//! Run: `cargo run --release --example views_and_gateways`

use sage::clovis::Client;
use sage::config::Testbed;
use sage::gateway::hdf5::{Dtype, H5File};
use sage::gateway::posix::PosixGateway;
use sage::gateway::s3::S3View;
use sage::tools::analytics::{Pipeline, Plan, Sink};
use sage::tools::rthms::Rthms;

fn main() -> sage::Result<()> {
    let mut c = Client::new_sim(Testbed::sage_prototype());

    // --- POSIX gateway -------------------------------------------------
    let fs = PosixGateway::mount(&mut c)?;
    fs.mkdir(&mut c, "/campaign")?;
    fs.create(&mut c, "/campaign/notes.txt")?;
    fs.write(&mut c, "/campaign/notes.txt", 0, b"shot 42: interesting tail")?;
    println!(
        "[posix] /campaign/notes.txt = {:?}",
        String::from_utf8_lossy(&fs.read(&mut c, "/campaign/notes.txt", 0, 64)?)
    );

    // --- HDF5 view ------------------------------------------------------
    let h5 = H5File::create(&mut c);
    h5.create_group(&mut c, "/diagnostics")?;
    let ds = h5.create_dataset(&mut c, "/diagnostics/energy", Dtype::F32, &[256, 64])?;
    let samples: Vec<f32> = (0..256 * 64).map(|i| ((i % 97) as f32).sin().abs() * 40.0).collect();
    h5.write_f32(&mut c, "/diagnostics/energy", 0, &samples)?;
    h5.set_attr(&mut c, "/diagnostics/energy", "units", "keV")?;
    println!(
        "[hdf5] /diagnostics/energy {:?} {} elems, units={}",
        ds.shape,
        ds.len(),
        h5.attr(&c, "/diagnostics/energy", "units")?
    );

    // --- S3 view over the SAME dataset object (zero copy) ---------------
    let s3 = S3View::new(&mut c);
    s3.link_object(&mut c, "exports", "energy.raw", ds.obj, ds.len() * 4)?;
    let listed = s3.list(&c, "exports", "")?;
    println!("[s3] exports/: {listed:?} (same object, no copy)");
    let via_s3 = s3.get_object(&mut c, "exports", "energy.raw")?;
    let first = f32::from_le_bytes(via_s3[0..4].try_into().unwrap());
    assert_eq!(first, samples[0], "views agree on the bytes");
    println!("[s3] first element via S3 == HDF5 write: {first}");

    // --- analytics: histogram pushes down into storage -------------------
    let job = Pipeline::new(Sink::Histogram { lo: 0.0, hi: 40.0 });
    let (result, plan) = job.run(&mut c, ds.obj, ds.len())?;
    assert_eq!(plan, Plan::InStorage);
    if let sage::tools::analytics::JobResult::Histogram(counts) = result {
        let busiest = counts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        println!(
            "[analytics] histogram computed IN STORAGE; busiest bin {} ({} records)",
            busiest.0, busiest.1
        );
    }
    // a filtered mean cannot push down: planner goes client-side
    let job2 = Pipeline::new(Sink::Mean).filter(|v| v > 1.0);
    let (_, plan2) = job2.run(&mut c, ds.obj, ds.len())?;
    assert_eq!(plan2, Plan::ClientSide);
    println!("[analytics] filtered mean fell back to client-side (as planned)");

    // --- RTHMS: placement recommendations from the live trace ------------
    let mut rthms = Rthms::new();
    rthms.ingest(&c.fdmi.drain());
    let recs = rthms.recommend(&Testbed::sage_prototype(), 512 << 20);
    println!("[rthms] {} objects profiled; top recommendations:", recs.len());
    for r in recs.iter().take(3) {
        println!(
            "   obj {:?} -> {:?} (est access {})",
            r.obj,
            r.tier,
            sage::metrics::fmt_secs(r.est_access)
        );
    }
    Ok(())
}
