//! Function shipping (§3.2.1 "Minimize Data Movement"): run the ALF
//! log-analytics histogram *in storage* via the AOT Pallas kernel and
//! compare against moving the raw logs to the client.
//!
//! Run: `make artifacts && cargo run --release --example function_shipping`

use sage::apps::alf;
use sage::clovis::Client;
use sage::config::Testbed;
use sage::metrics::Table;

fn main() -> sage::Result<()> {
    let tb = Testbed::sage_prototype();
    let mut client = match Client::new_with_runtime(tb) {
        Ok(c) => {
            println!("[runtime] PJRT executor attached (kernel offload active)");
            c
        }
        Err(e) => {
            println!("[runtime] artifacts unavailable ({e}); CPU fallback");
            Client::new_sim(Testbed::sage_prototype())
        }
    };

    let mut t = Table::new(
        "ALF log analytics: shipped vs moved",
        &["log size", "t shipped(s)", "t moved(s)", "speedup", "net saved"],
    );
    for n in [65_536usize, 262_144, 1_048_576] {
        let values = alf::generate_log_values(n, n as u64);
        let obj = alf::store_log(&mut client, &values)?;
        let base = client.now;
        let rep = alf::analyze(&mut client, obj, 0.0, 1024.0)?;
        // correctness: every record counted (padding lands in bin 0)
        let total: f32 = rep.counts.iter().sum();
        assert!(total >= n as f32, "histogram lost records: {total} < {n}");
        t.row(vec![
            sage::util::bytes::fmt_size((n * 4) as u64),
            format!("{:.4}", rep.t_shipped - base),
            format!("{:.4}", rep.t_moved - base),
            format!("{:.1}x", (rep.t_moved - base) / (rep.t_shipped - base)),
            sage::util::bytes::fmt_size(rep.net_bytes_moved - rep.net_bytes_shipped),
        ]);
    }
    print!("{}", t.render());

    // show the histogram itself for the largest log
    let values = alf::generate_log_values(1_048_576, 99);
    let obj = alf::store_log(&mut client, &values)?;
    let rep = alf::analyze(&mut client, obj, 0.0, 256.0)?;
    println!("\nrequest-size distribution (64 bins over 0..256 MB):");
    let max = rep.counts.iter().cloned().fold(1.0f32, f32::max);
    for (i, chunk) in rep.counts.chunks(8).enumerate() {
        let s: f32 = chunk.iter().sum();
        let bar = "#".repeat((s / max * 6.0) as usize + 1);
        println!("  [{:3}-{:3}) {:>9.0} {bar}", i * 32, (i + 1) * 32, s);
    }
    Ok(())
}
