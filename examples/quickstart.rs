//! Quickstart: the SAGE storage API in five minutes.
//!
//! Creates a client over the simulated SAGE prototype, walks through
//! objects, indices, containers, layouts, transactions and function
//! shipping — the §3.2.2 Clovis API surface.
//!
//! Run: `cargo run --release --example quickstart`

use sage::clovis::{Client, FunctionKind};
use sage::config::Testbed;
use sage::mero::Layout;
use sage::sim::device::DeviceKind;

fn main() -> sage::Result<()> {
    // 1. a client over the SAGE prototype rack (4 storage tiers)
    let mut client = Client::new_sim(Testbed::sage_prototype());
    println!("== SAGE quickstart on {} ==", "sage_prototype");

    // 2. objects: arrays of power-of-2 blocks, striped 4+1 over SSD
    let obj = client.create_object(4096)?;
    let payload: Vec<u8> = (0..512 * 1024u32).map(|i| (i % 199) as u8).collect();
    let t = client.write_object(&obj, 0, &payload)?;
    println!("wrote {} in {:.2} ms (SNS 4+1 striping + parity)",
        sage::util::bytes::fmt_size(payload.len() as u64), t * 1e3);
    let back = client.read_object(&obj, 0, payload.len() as u64)?;
    assert_eq!(back, payload);
    println!("read back OK");

    // 3. explicit layouts: mirror on NVRAM for a hot metadata object
    let hot = client.create_object_with(
        4096,
        Layout::Mirror { copies: 3, tier: DeviceKind::Nvram },
    )?;
    client.write_object(&hot, 0, &vec![7u8; 4096])?;
    println!("mirrored object on NVRAM tier: 3 copies");

    // 4. KV indices: GET/PUT/DEL/NEXT
    let idx = client.create_index();
    client.idx_put(idx, vec![
        (b"ipic3d/step".to_vec(), b"42".to_vec()),
        (b"ipic3d/dt".to_vec(), b"0.05".to_vec()),
    ])?;
    let next = client.idx_next(idx, &[b"ipic3d/".to_vec()])?;
    println!("NEXT(ipic3d/) -> {:?}",
        next[0].as_ref().map(|(k, _)| String::from_utf8_lossy(k).to_string()));

    // 5. containers group objects; tier hints steer placement
    let cont = client.create_container("simulation-output", Some(DeviceKind::Ssd));
    client.container_add(cont, obj)?;
    client.container_add(cont, hot)?;

    // 6. distributed transactions: atomic multi-key updates
    let tx = client.tx_begin();
    client.tx_put(tx, b"manifest/objects".to_vec(), b"2".to_vec())?;
    client.tx_put(tx, b"manifest/bytes".to_vec(), b"528384".to_vec())?;
    client.tx_commit(tx)?;
    println!("transaction committed at epoch {}", client.store.dtm.epoch());

    // 7. function shipping: compute where the data lives
    let r = client.ship_to_object(obj, FunctionKind::IntegrityCheck)?;
    println!(
        "shipped integrity scrub: {} over the wire instead of {}",
        sage::util::bytes::fmt_size(r.net_bytes),
        sage::util::bytes::fmt_size(r.net_bytes_moved),
    );

    // 8. one-shot container op (§3.2.1): scrub everything in the group
    let results = client.ship_to_container(cont, FunctionKind::IntegrityCheck)?;
    println!("container scrub: {} objects verified", results.len());

    // 9. telemetry: the ADDB report
    println!("\n{}", client.addb.report());
    Ok(())
}
