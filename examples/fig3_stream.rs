//! Regenerate Figure 3 (all three panels) from the CLI harness.
//!
//! Run: `cargo run --release --example fig3_stream`

use sage::apps::stream;
use sage::config::Testbed;
use sage::metrics::Table;
use sage::pgas::{StorageTarget, WindowKind};

fn main() -> sage::Result<()> {
    // (a) Blackdog: storage windows ~ memory windows
    let tb = Testbed::blackdog();
    let mut t = Table::new(
        "Fig 3(a) STREAM on Blackdog (MB/s, triad)",
        &["Melems", "memory", "storage(hdd)", "degradation"],
    );
    for m in [10, 50, 100, 500, 1000] {
        let mem = stream::run(&tb, WindowKind::Memory, m, 3)?;
        let sto = stream::run(&tb, WindowKind::Storage(StorageTarget::Hdd), m, 3)?;
        t.row(vec![
            m.to_string(),
            format!("{:.0}", mem[3].bandwidth / 1e6),
            format!("{:.0}", sto[3].bandwidth / 1e6),
            format!("{:.1}%", (1.0 - sto[3].bandwidth / mem[3].bandwidth) * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!("(paper: ~10% degradation at 1000M elements)\n");

    // (b) Lustre asymmetry
    let tegner = Testbed::tegner();
    let (r, w) = stream::rw_asymmetry(&tegner, StorageTarget::Pfs, 4 << 30)?;
    println!("Fig 3(b) Lustre: read {:.0} MB/s vs write {:.0} MB/s", r / 1e6, w / 1e6);
    println!("(paper: 12,308 MB/s read, 1,374 MB/s write)\n");

    // (c) Tegner: Lustre-backed STREAM collapses
    let mut t = Table::new(
        "Fig 3(c) STREAM on Tegner (MB/s, triad)",
        &["Melems", "memory", "storage(pfs)", "degradation"],
    );
    for m in [10, 100, 1000] {
        let mem = stream::run(&tegner, WindowKind::Memory, m, 2)?;
        let sto = stream::run(&tegner, WindowKind::Storage(StorageTarget::Pfs), m, 2)?;
        t.row(vec![
            m.to_string(),
            format!("{:.0}", mem[3].bandwidth / 1e6),
            format!("{:.0}", sto[3].bandwidth / 1e6),
            format!("{:.1}%", (1.0 - sto[3].bandwidth / mem[3].bandwidth) * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!("(paper: ~90% degradation — write-bandwidth limited)");
    Ok(())
}
