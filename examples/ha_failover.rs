//! HA walkthrough (§3.2.1): failures are the norm, the storage stays
//! available.
//!
//! Devices fail (hard + transient) under an exponential failure
//! schedule; the HA subsystem analyzes the quasi-ordered event set and
//! engages SNS repair — or a proactive drain when a device degrades
//! (repeated transients) before hard-failing; reads served during the
//! degraded window reconstruct through parity, and after recovery the
//! data has full redundancy again.
//!
//! Run: `cargo run --release --example ha_failover`

use sage::cluster::failure::{FailureKind, FailureSchedule};
use sage::clovis::Client;
use sage::config::Testbed;
use sage::mero::ha::RepairAction;
use sage::mero::sns;
use sage::sim::rng::SimRng;

fn main() -> sage::Result<()> {
    let mut client = Client::new_sim(Testbed::sage_prototype());
    let mut rng = SimRng::new(7);

    // a working set of striped objects
    let mut objs = Vec::new();
    let mut payloads = Vec::new();
    for i in 0..12u64 {
        let o = client.create_object(4096)?;
        let mut data = vec![0u8; 256 * 1024];
        SimRng::new(i).fill_bytes(&mut data);
        client.write_object(&o, 0, &data)?;
        objs.push(o);
        payloads.push(data);
    }
    println!("stored {} striped objects (SNS 4+1)", objs.len());

    // exponential failure schedule over the SSD pool
    let ssds: Vec<usize> = client
        .store
        .cluster
        .devices_where(|d| d.profile.kind == sage::sim::device::DeviceKind::Ssd);
    let mut schedule =
        FailureSchedule::sampled(&ssds, 400.0, 600.0, 0.5, &mut rng);
    println!("sampled {} failure events over 600s", schedule.remaining());

    let mut t = 0.0;
    let mut repairs = 0;
    let mut degraded_reads = 0;
    while t < 600.0 {
        t += 30.0;
        for ev in schedule.due(t) {
            let store = &mut client.store;
            // cluster applies the fault
            if let FailureKind::Device(d) = ev.kind {
                store.cluster.fail_device(d);
            }
            // HA subsystem decides
            let nodes: Vec<Option<usize>> = (0..store.cluster.devices.len())
                .map(|d| store.cluster.node_of(d))
                .collect();
            let action = store.ha.observe(ev, |d| nodes[d]);
            match action {
                RepairAction::RebuildDevice(d) => {
                    println!("t={t:6.0}s  device {d} failed -> SNS rebuild");
                    // reads still work while degraded
                    let (back, _) =
                        sns::read(store, objs[0], 0, 4096, t)?;
                    assert_eq!(&back[..], &payloads[0][..4096]);
                    degraded_reads += 1;
                    let (bytes, t_done) = sns::repair(store, &objs, d, t)?;
                    store.cluster.replace_device(d);
                    store.ha.repair_done(d, t_done);
                    repairs += 1;
                    println!(
                        "t={t:6.0}s  rebuilt {} in {:.2}s",
                        sage::util::bytes::fmt_size(bytes),
                        t_done - t
                    );
                }
                RepairAction::ProactiveDrain(d) => {
                    println!("t={t:6.0}s  device {d}: repeated transients -> proactive drain");
                    // the recovery plane executes the drain: units are
                    // read off the still-live device and re-homed at
                    // their own read frontiers; the device stays in
                    // service and a later hard failure of it has
                    // nothing left to rebuild
                    let (bytes, t_done) = sns::drain(store, &objs, d, t)?;
                    store.ha.repair_done(d, t_done);
                    println!(
                        "t={t:6.0}s  drained {} off device {d} in {:.2}s",
                        sage::util::bytes::fmt_size(bytes),
                        t_done - t
                    );
                }
                RepairAction::NodeAlert { node, events } => {
                    println!("t={t:6.0}s  node {node}: {events} correlated events -> operator alert");
                }
                RepairAction::None => {}
            }
        }
    }

    // every object still fully readable
    for (o, p) in objs.iter().zip(payloads.iter()) {
        let back = client.store.read_object(*o, 0, p.len() as u64, t)?.0;
        assert_eq!(&back, p, "object survived the failure storm");
    }
    println!(
        "\nsurvived: {repairs} rebuilds, {degraded_reads} degraded reads, \
         all {} objects byte-identical",
        objs.len()
    );
    println!(
        "HA counters: {} repairs, {} drains, {} alerts, \
         mean repair {:.2}s",
        client.store.ha.repairs_started,
        client.store.ha.drains_started,
        client.store.ha.alerts,
        client.store.ha.mean_repair_time()
    );
    Ok(())
}
