//! HSM walkthrough: usage-driven data movement across the SAGE tiers
//! (§3.2.3), wired through FDMI (§3.2.2) exactly as a third-party
//! plugin would be.
//!
//! A skewed (zipfian) workload touches a population of objects; the
//! heat-weighted policy promotes the hot set to NVRAM and demotes cold
//! objects toward the archive tier, while every byte is preserved.
//!
//! Run: `cargo run --release --example hsm_tiering`

use sage::clovis::Client;
use sage::config::Testbed;
use sage::hsm::{Hsm, TieringPolicy};
use sage::metrics::Table;
use sage::sim::device::DeviceKind;
use sage::sim::rng::SimRng;

fn main() -> sage::Result<()> {
    let mut client = Client::new_sim(Testbed::sage_prototype());
    let mut hsm = Hsm::new(TieringPolicy::HeatWeighted);
    let mut rng = SimRng::new(2026);

    // population: 40 objects of 256 KiB each, initially on SSD
    let mut objs = Vec::new();
    let payload: Vec<u8> = (0..256 * 1024u32).map(|i| (i % 223) as u8).collect();
    for _ in 0..40 {
        let o = client.create_object(4096)?;
        client.write_object(&o, 0, &payload)?;
        objs.push(o);
    }

    // zipfian access: low indices are hot
    for round in 0..400 {
        let pick = rng.gen_zipf(objs.len() as u64, 0.8) as usize;
        client.read_object(&objs[pick], 0, 65536)?;
        if round % 50 == 0 {
            // HSM consumes the FDMI event stream periodically
            let records = client.fdmi.drain();
            hsm.observe(&records, &client.store);
        }
    }
    let records = client.fdmi.drain();
    hsm.observe(&records, &client.store);

    // plan + migrate
    let now = client.now;
    let plan = hsm.plan(now);
    println!("HSM planned {} migrations at t={now:.2}s", plan.len());
    let t_done = hsm.migrate(&mut client.store, &plan, now)?;
    println!(
        "migrated {} across tiers in {:.2}s of storage time",
        sage::util::bytes::fmt_size(hsm.bytes_moved),
        t_done - now
    );

    // verify: no byte lost, and the hottest object went up a tier
    let mut tiers = Table::new("tier placement after HSM", &["object", "score", "tier"]);
    for (i, o) in objs.iter().enumerate().take(10) {
        let tier = client.store.object(*o)?.layout.tier();
        tiers.row(vec![
            format!("obj{i}"),
            format!("{:.1}", hsm.score(*o, now)),
            format!("{tier:?}"),
        ]);
    }
    print!("{}", tiers.render());

    let hottest = client.store.object(objs[0])?.layout.tier();
    assert_eq!(hottest, DeviceKind::Nvram, "hot object should live on NVRAM");
    let back = client.read_object(&objs[0], 0, payload.len() as u64)?;
    assert_eq!(back, payload, "migration preserved every byte");
    println!("hot object promoted to NVRAM; bytes verified intact");
    Ok(())
}
