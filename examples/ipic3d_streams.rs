//! End-to-end driver (Fig 6 + Fig 7): the full SAGE pipeline on a real
//! workload.
//!
//! A genuine mini particle-in-cell simulation runs for 200 steps;
//! high-energy particles are streamed (MPI-streams analog) to consumer
//! ranks whose attached computation is the AOT-compiled Pallas
//! `postprocess` kernel executed through PJRT (CPU fallback when
//! artifacts are absent); consumers emit legacy-VTK snapshots a
//! ParaView user could open. Afterwards the Fig 7 scaling comparison
//! (streams vs collective I/O) runs on the Beskow model.
//!
//! This is the "end-to-end validation" example: all three layers
//! compose — rust coordinator (L3) -> PJRT artifact (L2) -> Pallas
//! kernel (L1). Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example ipic3d_streams`

use sage::apps::ipic3d;
use sage::config::Testbed;
use sage::metrics::Table;
use sage::runtime::Executor;

fn main() -> sage::Result<()> {
    let exec = match Executor::load_default() {
        Ok(e) => {
            println!("[runtime] artifacts loaded: {:?}", {
                let mut v = e.variants();
                v.sort();
                v
            });
            Some(e)
        }
        Err(e) => {
            println!("[runtime] no artifacts ({e}); CPU fallback");
            None
        }
    };

    // --- Fig 6: real pipeline with VTK output -------------------------
    let tb = Testbed::beskow();
    let vtk_dir = std::path::PathBuf::from("target/ipic3d_vtk");
    std::fs::create_dir_all(&vtk_dir)?;
    let t0 = std::time::Instant::now();
    let (hot, files) = ipic3d::run_real_pipeline(
        &tb,
        exec.as_ref(),
        20_000, // particles
        200,    // steps
        1.5,    // energy threshold
        Some(&vtk_dir),
    )?;
    println!(
        "[fig6] streamed {hot} high-energy particle records over 200 steps; \
         {files} VTK snapshots in {} ({:.1}s wall)",
        vtk_dir.display(),
        t0.elapsed().as_secs_f64()
    );

    // sanity: the VTK files are real and well-formed
    let sample = std::fs::read_to_string(vtk_dir.join("step_0199.vtk"))?;
    assert!(sample.starts_with("# vtk DataFile"));
    let points = sample
        .lines()
        .find(|l| l.starts_with("POINTS"))
        .and_then(|l| l.split_whitespace().nth(1))
        .unwrap_or("0")
        .to_string();
    println!("[fig6] final snapshot holds {points} tracked particles");

    // --- Fig 7: scaling comparison ------------------------------------
    let mut t = Table::new(
        "Fig 7: iPIC3D visualization I/O — collective vs streams (100 steps)",
        &["procs", "collective(s)", "streams(s)", "improvement"],
    );
    let mut p = 64;
    while p <= 8192 {
        let pt = ipic3d::run_scaling(&tb, p, 100);
        t.row(vec![
            p.to_string(),
            format!("{:.1}", pt.t_collective),
            format!("{:.1}", pt.t_streams),
            format!("{:.2}x", pt.improvement),
        ]);
        p *= 4;
    }
    print!("{}", t.render());
    println!("(paper: comparable at small scale, 3.6x at 8192 procs)");
    Ok(())
}
